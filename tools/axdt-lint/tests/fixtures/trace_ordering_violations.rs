//@ path: rust/src/coordinator/driver.rs
//@ expect: trace-ordering@14
//@ expect: trace-ordering@21
//@ partial: trace-ordering
//@ expect-partial: trace-ordering@14
//@ expect-partial: trace-ordering@21

// A Submitted/Executed record journaled after the send it describes
// has lost its causal-ordering contract: record first, then send.

impl Driver {
    fn notify(&self, tx: &Sender<Msg>, now_ns: u64) {
        let _ = tx.send(Msg::Nudge);
        self.metrics.trace.record(now_ns, TraceKind::Submitted { shard: 0, problem: 7, width: 4 });
    }

    fn flush(&self, replies: &[ReplySender], now_ns: u64) {
        for r in replies {
            let _ = r.send(self.result());
        }
        self.metrics.trace.record(now_ns, TraceKind::Executed { shard: 1, problem: 7, width: 4 });
    }

    fn submit_traced(&self, tx: &Sender<Msg>, now_ns: u64) {
        self.metrics.trace.record(now_ns, TraceKind::Submitted { shard: 0, problem: 7, width: 4 });
        let _ = tx.send(Msg::Job);
    }

    fn journal_only(&self, now_ns: u64) {
        self.metrics.trace.record(now_ns, TraceKind::Executed { shard: 1, problem: 7, width: 4 });
    }
}
