//@ path: rust/tests/shard_pool.rs
//@ expect: no-sleep-in-tests@10
//@ expect: no-sleep-in-tests@11
//@ expect: no-sleep-in-tests@14
//@ expect: no-sleep-in-tests@17

#[test]
fn pool_settles() {
    // thread::sleep(Duration::from_secs(60)) in a comment must not fire.
    thread::sleep(Duration::from_millis(250));
    std::thread::sleep(std::time::Duration::from_secs(2));
    thread::sleep(Duration::from_millis(100));
    thread::sleep(Duration::from_micros(500));
    thread::sleep(Duration::from_millis(150_000));
    let backoff = config.backoff();
    let log = "thread::sleep(Duration::from_secs(9))";
    thread::sleep(backoff);
    let _ = log;
}
