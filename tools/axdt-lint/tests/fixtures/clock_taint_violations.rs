//@ path: rust/src/coordinator/driver.rs
//@ expect: clock-taint@16
//@ expect: clock-taint@23
//@ expect: clock-taint@28
//@ expect: clock-taint@34
//@ partial: clock-taint
//@ expect-partial: clock-taint@16
//@ expect-partial: clock-taint@23
//@ expect-partial: clock-taint@28
//@ expect-partial: clock-taint@34

// Wall-derived values must never reach deadline arithmetic: the seam
// is the injected Clock, even where the wall read itself is allowed.

fn arm(started: Instant) -> u64 {
    let deadline_ns = started.elapsed().as_nanos() as u64;
    deadline_ns
}

fn wait_reply(started: Instant, rx: &Receiver<Reply>) -> Option<Reply> {
    let waited = started.elapsed();
    let budget = waited;
    rx.recv_timeout(budget).ok()
}

fn repoll(started: Instant, clock: &SystemClock) -> Duration {
    let lag_ns = started.elapsed().as_nanos() as u64;
    clock.wait_budget(lag_ns)
}

fn chained(started: Instant, rx: &Receiver<Reply>) -> Option<Reply> {
    let base_ns = started.elapsed().as_nanos() as u64;
    let padded_ns = base_ns + GRACE_NS;
    rx.recv_timeout(Duration::from_nanos(padded_ns)).ok()
}

fn observe(started: Instant, hist: &mut Histogram) {
    let lag_ns = started.elapsed().as_nanos() as u64;
    hist.record_ns(lag_ns);
}
