//@ path: rust/src/coordinator/driver.rs
//@ expect: clock-seam@13

// A #[cfg(test)] item nested inside a #[cfg(not(test))] module is test
// code; the rest of the not(test) module is still production.

#[cfg(not(test))]
mod timing {
    #[cfg(test)]
    mod fakes {
        fn wall_sample() { let _ = Instant::now(); }
    }
    fn prod_wall_read() { let _ = Instant::now(); }
}
