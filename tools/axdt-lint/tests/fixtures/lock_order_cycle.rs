//@ path: rust/src/util/pool.rs
//@ expect: lock-order@13
//@ expect: lock-order@20
//@ partial: lock-order
//@ expect-partial: lock-order@13
//@ expect-partial: lock-order@20

// Seeded AB/BA deadlock: `stats` is taken under `queue` in drain() and
// `queue` under `stats` in reset() — the classic lock-order cycle.

fn drain(queue: &Mutex<Vec<Job>>, stats: &Mutex<Totals>) {
    let q = lock_recover(queue);
    let mut s = lock_recover(stats);
    s.drained += q.len() as u64;
}

fn reset(queue: &Mutex<Vec<Job>>, stats: &Mutex<Totals>) {
    let mut s = lock_recover(stats);
    s.drained = 0;
    lock_recover(queue).clear();
}
