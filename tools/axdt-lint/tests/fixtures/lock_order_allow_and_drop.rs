//@ path: rust/src/util/pool.rs

// No cycle fires here: the reverse acquisition in reset_at_boot() is
// justified (startup-only, single-threaded), so its edge is dropped
// before cycle detection; in tally() the first guard dies at drop(),
// so no edge forms at all.

fn drain(queue: &Mutex<Vec<Job>>, stats: &Mutex<Totals>) {
    let q = lock_recover(queue);
    let mut s = lock_recover(stats);
    s.drained += q.len() as u64;
}

fn reset_at_boot(queue: &Mutex<Vec<Job>>, stats: &Mutex<Totals>) {
    let mut s = lock_recover(stats);
    s.drained = 0;
    // axdt-lint: allow(lock-order): boot-time path, no drain() can run concurrently
    lock_recover(queue).clear();
}

fn tally(queue: &Mutex<Vec<Job>>, stats: &Mutex<Totals>) -> u64 {
    let q = lock_recover(queue);
    let n = q.len() as u64;
    drop(q);
    let mut s = lock_recover(stats);
    s.drained = n;
    n
}
