//! The architectural rule registry.
//!
//! Every rule is a token-sequence matcher scoped by (relative) path and
//! by the test-token mask (`lexer::test_token_mask`): test code is
//! allowed to use wall time, blocking-eval baselines and unwraps.
//!
//! | rule | enforces |
//! |------|----------|
//! | `clock-seam` | no `Instant::now()` / `SystemTime::now()` / `thread::sleep` outside `util/clock.rs` + `util/testbed.rs` |
//! | `ticket-seam` | blocking `pool/svc/service.eval(` and `.eval_typed(` confined to the pool + facade |
//! | `no-sleep-in-tests` | `rust/tests/` sleeps: literal `Duration` ≤ 100 ms only |
//! | `panic-free-workers` | no `.unwrap()` / `.expect(` / `panic!` on worker paths |
//! | `mutex-discipline` | `.lock().unwrap()` forbidden — use `util::sync::lock_recover` |
//!
//! Suppression: `// axdt-lint: allow(<rule>): <justification>` on the
//! flagged line or the line directly above.  The justification is
//! mandatory — an allow without one is itself a diagnostic (`bad-allow`)
//! and does NOT suppress.

use crate::lexer::{lex, test_token_mask, Comment, TokKind, Token};

/// A single finding, formatted as `path:line:col: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub path: String,
    pub line: u32,
    pub col: u32,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}:{}: [{}] {}",
            self.path, self.line, self.col, self.rule, self.message
        )
    }
}

pub const CLOCK_SEAM: &str = "clock-seam";
pub const TICKET_SEAM: &str = "ticket-seam";
pub const NO_SLEEP_IN_TESTS: &str = "no-sleep-in-tests";
pub const PANIC_FREE_WORKERS: &str = "panic-free-workers";
pub const MUTEX_DISCIPLINE: &str = "mutex-discipline";
/// Meta-rule: a malformed suppression comment (missing justification or
/// unknown rule id).  Always active — an allow that suppresses nothing
/// silently is how guards rot.
pub const BAD_ALLOW: &str = "bad-allow";

/// The enforceable rules, in reporting order (`bad-allow` is a meta-rule
/// and not selectable).
pub const ALL_RULES: &[(&str, &str)] = &[
    (
        CLOCK_SEAM,
        "Instant::now()/SystemTime::now()/thread::sleep outside util/clock.rs and \
         util/testbed.rs: deadline decisions must read the injected Clock",
    ),
    (
        TICKET_SEAM,
        "blocking pool/service eval outside coordinator/{shard,service}.rs: evaluation \
         must flow through the two-phase submit/wait ticket path",
    ),
    (
        NO_SLEEP_IN_TESTS,
        "thread::sleep in rust/tests/ longer than 100 ms or with a non-literal duration: \
         timing tests run on ManualClock",
    ),
    (
        PANIC_FREE_WORKERS,
        "unwrap()/expect()/panic! in coordinator/{shard,service}.rs or fitness/ non-test \
         code: workers answer with typed ServiceErrors, they never die",
    ),
    (
        MUTEX_DISCIPLINE,
        ".lock().unwrap() where util::sync::lock_recover exists: a poisoned mutex must \
         not cascade panics across clients",
    ),
];

pub fn rule_ids() -> Vec<&'static str> {
    ALL_RULES.iter().map(|(id, _)| *id).collect()
}

/// Longest sleep a test may take on the wall clock (matches the retired
/// `scripts/forbid_long_sleeps.sh` budget).
const SLEEP_LIMIT_MS: f64 = 100.0;

/// Per-path rule scoping, derived from the repo-relative path (forward
/// slashes).  Mirrors the seams' documented homes, so moving a seam file
/// means updating this table — which is exactly the review conversation
/// the linter exists to force.
struct Scope {
    clock_seam: bool,
    ticket_seam: bool,
    sleep_rule: bool,
    panic_free: bool,
    mutex_rule: bool,
}

fn scope_for(path: &str) -> Scope {
    let in_src = path.starts_with("rust/src/");
    let in_tests = path.starts_with("rust/tests/");
    let clock_exempt =
        path.ends_with("util/clock.rs") || path.ends_with("util/testbed.rs");
    let ticket_exempt =
        path.ends_with("coordinator/shard.rs") || path.ends_with("coordinator/service.rs");
    let worker_path = path.ends_with("coordinator/shard.rs")
        || path.ends_with("coordinator/service.rs")
        || path.starts_with("rust/src/fitness/");
    Scope {
        clock_seam: in_src && !clock_exempt,
        ticket_seam: in_src && !ticket_exempt,
        sleep_rule: in_tests,
        panic_free: in_src && worker_path,
        mutex_rule: in_src,
    }
}

/// Lint one source file under its repo-relative `path`.  `active` filters
/// which rules run (empty = all); `bad-allow` findings are only reported
/// for allows naming an active rule, so a partial run (`--rule X`) never
/// fails on another rule's suppressions.
pub fn lint_source(path: &str, source: &str, active: &[&str]) -> Vec<Diagnostic> {
    let lexed = lex(source);
    let mask = test_token_mask(&lexed.tokens);
    let scope = scope_for(path);
    let on = |rule: &str| active.is_empty() || active.contains(&rule);

    let mut raw: Vec<Diagnostic> = Vec::new();
    let toks = &lexed.tokens;

    for i in 0..toks.len() {
        let t = &toks[i];
        // Seam rules skip test code (wall time, blocking baselines and
        // unwraps are fine there); the sleep rule is test code's own
        // budget and must NOT skip it — in `rust/tests/` every sleep
        // lives inside a `#[test]` fn.
        let prod = !mask[i];

        if prod && scope.clock_seam && on(CLOCK_SEAM) {
            if (t.is_ident("Instant") || t.is_ident("SystemTime"))
                && seq(toks, i + 1, &[":", ":", "now", "("])
            {
                raw.push(diag(path, t, CLOCK_SEAM, format!(
                    "{}::now() bypasses the injected Clock (util::clock); thread a `Clock` \
                     through and read `now_ns()`",
                    ident_text(t)
                )));
            }
            if t.is_ident("thread") && seq(toks, i + 1, &[":", ":", "sleep"]) {
                raw.push(diag(
                    path,
                    t,
                    CLOCK_SEAM,
                    "thread::sleep in production code: deadlines and backoff must be \
                     driven by the injected Clock"
                        .to_string(),
                ));
            }
        }

        if prod && scope.ticket_seam && on(TICKET_SEAM) && t.is_punct('.') {
            // `.eval(` with a pool-ish receiver: `pool`, `svc`, `service`
            // idents or a `pool()` call directly before the dot.
            if seq(toks, i + 1, &["eval", "("]) {
                let recv_ident = i
                    .checked_sub(1)
                    .map(|p| {
                        toks[p].is_ident("pool")
                            || toks[p].is_ident("svc")
                            || toks[p].is_ident("service")
                    })
                    .unwrap_or(false);
                let recv_call = i >= 3
                    && toks[i - 1].is_punct(')')
                    && toks[i - 2].is_punct('(')
                    && toks[i - 3].is_ident("pool");
                if recv_ident || recv_call {
                    raw.push(diag(
                        path,
                        &toks[i + 1],
                        TICKET_SEAM,
                        "blocking eval on the pool/service outside the adapter: issue a \
                         ticket via submit(..) and redeem it with wait(..)"
                            .to_string(),
                    ));
                }
            }
            if seq(toks, i + 1, &["eval_typed", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 1],
                    TICKET_SEAM,
                    "blocking eval_typed outside the adapter: issue a ticket via \
                     submit_typed(..) and redeem it with wait_typed(..)"
                        .to_string(),
                ));
            }
        }

        if scope.sleep_rule
            && on(NO_SLEEP_IN_TESTS)
            && t.is_ident("thread")
            && seq(toks, i + 1, &[":", ":", "sleep", "("])
        {
            if let Some(d) = audit_sleep(path, toks, i) {
                raw.push(d);
            }
        }

        if prod && scope.panic_free && on(PANIC_FREE_WORKERS) {
            if t.is_punct('.') && seq(toks, i + 1, &["unwrap", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 1],
                    PANIC_FREE_WORKERS,
                    "unwrap() on a worker path: return a typed ServiceError (or use \
                     lock_recover) — a panicking worker strands every client of its shard"
                        .to_string(),
                ));
            }
            if t.is_punct('.') && seq(toks, i + 1, &["expect", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 1],
                    PANIC_FREE_WORKERS,
                    "expect() on a worker path: return a typed ServiceError — a panicking \
                     worker strands every client of its shard"
                        .to_string(),
                ));
            }
            if t.is_ident("panic") && toks.get(i + 1).is_some_and(|n| n.is_punct('!')) {
                raw.push(diag(
                    path,
                    t,
                    PANIC_FREE_WORKERS,
                    "panic! on a worker path: answer with a typed ServiceError instead"
                        .to_string(),
                ));
            }
        }

        if prod
            && scope.mutex_rule
            && on(MUTEX_DISCIPLINE)
            && t.is_punct('.')
            && seq(toks, i + 1, &["lock", "(", ")", "."])
            && (seq(toks, i + 5, &["unwrap", "("]) || seq(toks, i + 5, &["expect", "("]))
        {
            raw.push(diag(
                path,
                &toks[i + 5],
                MUTEX_DISCIPLINE,
                "raw .lock().unwrap(): use util::sync::lock_recover so a poisoned mutex \
                 recovers instead of cascading the panic"
                    .to_string(),
            ));
        }
    }

    apply_allows(path, raw, &lexed.comments, active)
}

fn ident_text(t: &Token) -> &str {
    match &t.kind {
        TokKind::Ident(i) => i,
        _ => "",
    }
}

fn diag(path: &str, at: &Token, rule: &'static str, message: String) -> Diagnostic {
    Diagnostic { path: path.to_string(), line: at.line, col: at.col, rule, message }
}

/// Match a sequence of idents / single-char puncts starting at `from`.
fn seq(toks: &[Token], from: usize, pat: &[&str]) -> bool {
    if from + pat.len() > toks.len() {
        return false;
    }
    pat.iter().enumerate().all(|(k, p)| {
        let t = &toks[from + k];
        let mut chars = p.chars();
        match (chars.next(), chars.next()) {
            (Some(c), None) if !c.is_alphanumeric() && c != '_' => t.is_punct(c),
            _ => t.is_ident(p),
        }
    })
}

/// Audit one `thread::sleep(` call in a test (token `i` = `thread`).
/// Auditable form: `thread::sleep([std::[time::]]Duration::from_X(<literal>))`.
/// Returns a diagnostic for an over-budget or non-literal duration.
fn audit_sleep(path: &str, toks: &[Token], i: usize) -> Option<Diagnostic> {
    // Argument tokens: from after `(` to its matching `)`.
    let open = i + 4;
    let mut depth = 0usize;
    let mut close = open;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                close = k;
                break;
            }
        }
    }
    if close <= open {
        // Unterminated call (malformed source): rustc owns that error.
        return None;
    }
    let arg = &toks[open + 1..close];

    // Strip an optional `std::` / `std::time::` path prefix.
    let mut a = arg;
    for prefix in ["std", "time"] {
        if a.first().is_some_and(|t| t.is_ident(prefix))
            && a.get(1).is_some_and(|t| t.is_punct(':'))
            && a.get(2).is_some_and(|t| t.is_punct(':'))
        {
            a = &a[3..];
        }
    }

    let auditable = a.len() == 7
        && a[0].is_ident("Duration")
        && a[1].is_punct(':')
        && a[2].is_punct(':')
        && matches!(a[3].kind, TokKind::Ident(_))
        && a[4].is_punct('(')
        && matches!(a[5].kind, TokKind::Num(_))
        && a[6].is_punct(')');
    if !auditable {
        return Some(diag(
            path,
            &toks[i],
            NO_SLEEP_IN_TESTS,
            "unauditable sleep duration (not a literal Duration::from_*): drive timing \
             through ManualClock or testbed::wait_until"
                .to_string(),
        ));
    }

    let ctor = ident_text(&a[3]).to_string();
    let raw = match &a[5].kind {
        TokKind::Num(n) => n.replace('_', ""),
        _ => return None,
    };
    // Strip a numeric suffix (u64, f32...) if present.
    let numeric: String = raw
        .chars()
        .take_while(|c| c.is_ascii_digit() || *c == '.')
        .collect();
    let value: f64 = match numeric.parse() {
        Ok(v) => v,
        Err(_) => {
            return Some(diag(
                path,
                &toks[i],
                NO_SLEEP_IN_TESTS,
                format!("unauditable sleep duration literal `{raw}`"),
            ))
        }
    };
    let ms = match ctor.as_str() {
        "from_millis" => value,
        "from_secs" => value * 1000.0,
        "from_secs_f32" | "from_secs_f64" => value * 1000.0,
        "from_micros" => value / 1000.0,
        "from_nanos" => value / 1_000_000.0,
        _ => {
            return Some(diag(
                path,
                &toks[i],
                NO_SLEEP_IN_TESTS,
                format!("unauditable sleep duration constructor `Duration::{ctor}`"),
            ))
        }
    };
    if ms > SLEEP_LIMIT_MS {
        return Some(diag(
            path,
            &toks[i],
            NO_SLEEP_IN_TESTS,
            format!(
                "sleep of {ms:.0} ms exceeds the {SLEEP_LIMIT_MS:.0} ms budget: drive \
                 timing through ManualClock or testbed::wait_until"
            ),
        ));
    }
    None
}

/// One parsed `axdt-lint: allow(<rule>)` suppression.
struct Allow {
    rule: String,
    justified: bool,
    line: u32,
    col: u32,
}

fn parse_allows(comments: &[Comment]) -> Vec<Allow> {
    let mut out = Vec::new();
    for c in comments {
        let mut rest = c.text.as_str();
        while let Some(pos) = rest.find("axdt-lint:") {
            rest = &rest[pos + "axdt-lint:".len()..];
            let Some(ap) = rest.find("allow(") else { continue };
            let after = &rest[ap + "allow(".len()..];
            let Some(cp) = after.find(')') else { continue };
            let rule = after[..cp].trim().to_string();
            // Justification: any non-empty text after the `)`, with
            // leading separator punctuation stripped.
            let tail = after[cp + 1..]
                .trim_start_matches(&[':', '-', '—', ' ', '\t'][..])
                .trim();
            out.push(Allow {
                rule,
                justified: !tail.is_empty(),
                line: c.line,
                col: c.col,
            });
            rest = &after[cp + 1..];
        }
    }
    out
}

/// Filter diagnostics through suppression comments and append `bad-allow`
/// findings for malformed ones.
fn apply_allows(
    path: &str,
    raw: Vec<Diagnostic>,
    comments: &[Comment],
    active: &[&str],
) -> Vec<Diagnostic> {
    let allows = parse_allows(comments);
    let on = |rule: &str| active.is_empty() || active.contains(&rule);
    let known = rule_ids();

    let mut out: Vec<Diagnostic> = raw
        .into_iter()
        .filter(|d| {
            !allows.iter().any(|a| {
                a.justified
                    && a.rule == d.rule
                    && (a.line == d.line || a.line + 1 == d.line)
            })
        })
        .collect();

    for a in &allows {
        if !known.contains(&a.rule.as_str()) {
            // Unknown rule ids only fail full runs: a partial run cannot
            // tell a typo from a rule it was asked not to load.
            if active.is_empty() {
                out.push(Diagnostic {
                    path: path.to_string(),
                    line: a.line,
                    col: a.col,
                    rule: BAD_ALLOW,
                    message: format!("allow names unknown rule `{}`", a.rule),
                });
            }
        } else if !a.justified && on(a.rule.as_str()) {
            out.push(Diagnostic {
                path: path.to_string(),
                line: a.line,
                col: a.col,
                rule: BAD_ALLOW,
                message: format!(
                    "allow({}) without a justification is ignored: write \
                     `// axdt-lint: allow({}): <why this exception is sound>`",
                    a.rule, a.rule
                ),
            });
        }
    }

    out.sort_by(|a, b| {
        (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule))
    });
    out
}
