//! The architectural rule registry.
//!
//! Two rule families share this file:
//!
//! * **token rules** — short token-sequence matchers, scoped by
//!   (relative) path and by the test-token mask
//!   (`lexer::test_token_mask`): test code is allowed to use wall time,
//!   blocking-eval baselines and unwraps;
//! * **flow rules** — intra-procedural dataflow over the function
//!   boundaries recovered by [`crate::parser`] and the def-use chains
//!   of [`crate::dataflow`]: a `Ticket` stored and never collected, two
//!   mutexes taken in opposite orders, a journal record emitted after
//!   the send it describes, wall time leaking into deadline arithmetic.
//!
//! | rule | enforces |
//! |------|----------|
//! | `clock-seam` | no `Instant::now()` / `SystemTime::now()` / `thread::sleep` outside `util/clock.rs` + `util/testbed.rs` |
//! | `ticket-seam` | blocking `pool/svc/service.eval(` and `.eval_typed(` confined to the pool + facade |
//! | `no-sleep-in-tests` | `rust/tests/` sleeps: literal `Duration` ≤ 100 ms only |
//! | `panic-free-workers` | no `.unwrap()` / `.expect(` / `panic!` on worker paths |
//! | `mutex-discipline` | `.lock().unwrap()` / `.lock().unwrap_or_else(` forbidden — use `util::sync::lock_recover` |
//! | `lock-order` | the global lock-acquisition-order graph is acyclic |
//! | `ticket-leak` | every submitted ticket flows into `wait()`/`collect()` |
//! | `trace-ordering` | `Submitted`/`Executed` journal records precede the send they describe |
//! | `clock-taint` | wall-time-derived values never reach deadline arithmetic |
//!
//! Suppression: `// axdt-lint: allow(<rule>): <justification>` on the
//! flagged line or the line directly above.  The justification is
//! mandatory — an allow without one is itself a diagnostic (`bad-allow`)
//! and does NOT suppress.

use crate::dataflow::{
    bindings, call_args, find_call, last_path_ident, method_receiver, uses_of, Binding,
    CallIndex,
};
use crate::lexer::{lex, test_token_mask, Comment, TokKind, Token};
use crate::parser::{enclosing_block_close, functions, statement_end, FnInfo};

/// A single finding, formatted as `path:line:col: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub path: String,
    pub line: u32,
    pub col: u32,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}:{}: [{}] {}",
            self.path, self.line, self.col, self.rule, self.message
        )
    }
}

/// One edge of the global lock-acquisition-order graph: while a guard
/// on `held` was live, a guard on `acquired` was taken.  Edges are
/// collected per file and cycle-checked across the whole tree
/// ([`lock_cycles`]), so an AB/BA split across two modules is still a
/// potential deadlock.
#[derive(Debug, Clone)]
pub struct LockEdge {
    pub held: String,
    pub acquired: String,
    /// Site of the inner (`acquired`) acquisition — where the
    /// diagnostic lands.
    pub path: String,
    pub line: u32,
    pub col: u32,
    /// Line of the outer (`held`) acquisition, for the message.
    pub held_line: u32,
}

/// Per-file analysis output: diagnostics plus the file's contribution
/// to the global lock-order graph.
#[derive(Debug, Default)]
pub struct FileAnalysis {
    pub diags: Vec<Diagnostic>,
    pub lock_edges: Vec<LockEdge>,
}

pub const CLOCK_SEAM: &str = "clock-seam";
pub const TICKET_SEAM: &str = "ticket-seam";
pub const NO_SLEEP_IN_TESTS: &str = "no-sleep-in-tests";
pub const PANIC_FREE_WORKERS: &str = "panic-free-workers";
pub const MUTEX_DISCIPLINE: &str = "mutex-discipline";
pub const LOCK_ORDER: &str = "lock-order";
pub const TICKET_LEAK: &str = "ticket-leak";
pub const TRACE_ORDERING: &str = "trace-ordering";
pub const CLOCK_TAINT: &str = "clock-taint";
/// Meta-rule: a malformed suppression comment (missing justification or
/// unknown rule id).  Always active — an allow that suppresses nothing
/// silently is how guards rot.
pub const BAD_ALLOW: &str = "bad-allow";

/// The enforceable rules, in reporting order (`bad-allow` is a meta-rule
/// and not selectable).
pub const ALL_RULES: &[(&str, &str)] = &[
    (
        CLOCK_SEAM,
        "Instant::now()/SystemTime::now()/thread::sleep outside util/clock.rs and \
         util/testbed.rs: deadline decisions must read the injected Clock",
    ),
    (
        TICKET_SEAM,
        "blocking pool/service eval outside coordinator/{shard,service}.rs: evaluation \
         must flow through the two-phase submit/wait ticket path",
    ),
    (
        NO_SLEEP_IN_TESTS,
        "thread::sleep in rust/tests/ longer than 100 ms or with a non-literal duration: \
         timing tests run on ManualClock",
    ),
    (
        PANIC_FREE_WORKERS,
        "unwrap()/expect()/panic! in coordinator/{shard,service}.rs or fitness/ non-test \
         code: workers answer with typed ServiceErrors, they never die",
    ),
    (
        MUTEX_DISCIPLINE,
        ".lock().unwrap() or inline .lock().unwrap_or_else(..) where \
         util::sync::lock_recover exists: poison recovery has exactly one spelling",
    ),
    (
        LOCK_ORDER,
        "a cycle in the global lock-acquisition-order graph (mutex B taken under mutex A \
         in one place, A under B in another) is a potential deadlock",
    ),
    (
        TICKET_LEAK,
        "a Ticket returned by submit()/submit_accuracy() that never flows into \
         wait()/collect() abandons in-flight work (#[must_use] cannot see \
         stored-and-forgotten tickets)",
    ),
    (
        TRACE_ORDERING,
        "a TraceKind::Submitted/Executed journal record must precede the channel send it \
         describes, or the journal loses its causal-ordering contract",
    ),
    (
        CLOCK_TAINT,
        "a wall-time-derived value (Instant::now()/SystemTime::now()/.elapsed()) flowing \
         into deadline arithmetic bypasses the injected Clock even when the read itself \
         was allowed",
    ),
];

pub fn rule_ids() -> Vec<&'static str> {
    ALL_RULES.iter().map(|(id, _)| *id).collect()
}

/// Longest sleep a test may take on the wall clock (the retired
/// `forbid_long_sleeps` budget).
const SLEEP_LIMIT_MS: f64 = 100.0;

/// Ticket-issuing calls (`ticket-leak` defs).
const SUBMITTERS: &[&str] = &["submit", "submit_typed", "submit_accuracy"];
/// Ticket-redeeming calls (`ticket-leak` sinks).  Iterator `.collect()`
/// never matches: a redeeming collect always has the ticket in its
/// argument list or as receiver, an iterator collect has empty args.
const COLLECTORS: &[&str] = &["wait", "wait_typed", "collect"];
/// Container methods that *store* a ticket: the receiver inherits the
/// obligation to reach a collector (or escape).
const CONTAINER_STORES: &[&str] = &["push", "push_back", "insert", "extend"];

/// Per-path rule scoping, derived from the repo-relative path (forward
/// slashes).  Mirrors the seams' documented homes, so moving a seam file
/// means updating this table — which is exactly the review conversation
/// the linter exists to force.
struct Scope {
    clock_seam: bool,
    ticket_seam: bool,
    sleep_rule: bool,
    panic_free: bool,
    mutex_rule: bool,
    lock_order: bool,
    ticket_leak: bool,
    trace_ordering: bool,
    clock_taint: bool,
}

fn scope_for(path: &str) -> Scope {
    let in_src = path.starts_with("rust/src/");
    let in_tests = path.starts_with("rust/tests/");
    let in_examples = path.starts_with("examples/");
    let in_tools = path.starts_with("tools/");
    let clock_exempt =
        path.ends_with("util/clock.rs") || path.ends_with("util/testbed.rs");
    let ticket_exempt =
        path.ends_with("coordinator/shard.rs") || path.ends_with("coordinator/service.rs");
    let worker_path = path.ends_with("coordinator/shard.rs")
        || path.ends_with("coordinator/service.rs")
        || path.starts_with("rust/src/fitness/");
    // util/sync.rs IS lock_recover — the one blessed home of the
    // `.lock().unwrap_or_else(` spelling the mutex rule bans elsewhere.
    let sync_home = path.ends_with("util/sync.rs");
    Scope {
        clock_seam: in_src && !clock_exempt,
        ticket_seam: in_src && !ticket_exempt,
        sleep_rule: in_tests,
        panic_free: in_src && worker_path,
        mutex_rule: (in_src && !sync_home) || in_examples || in_tools,
        lock_order: in_src || in_examples || in_tools,
        ticket_leak: in_src || in_examples,
        trace_ordering: in_src || in_examples,
        clock_taint: in_src && !clock_exempt,
    }
}

/// Lint one source file under its repo-relative `path` — the
/// single-file entry: intra-file lock-order cycles included.  `active`
/// filters which rules run (empty = all); `bad-allow` findings are only
/// reported for allows naming an active rule, so a partial run
/// (`--rule X`) never fails on another rule's suppressions.
pub fn lint_source(path: &str, source: &str, active: &[&str]) -> Vec<Diagnostic> {
    let mut analysis = analyze_source(path, source, active);
    analysis.diags.extend(lock_cycles(&analysis.lock_edges));
    analysis
        .diags
        .sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    analysis.diags
}

/// Analyze one file: all rules except the cross-file lock-order cycle
/// check, whose edges are returned for the caller to aggregate
/// (`lint_tree` unions them across the tree; [`lint_source`] closes
/// over just this file).  Suppressed acquisitions are already filtered
/// from `lock_edges`.
pub fn analyze_source(path: &str, source: &str, active: &[&str]) -> FileAnalysis {
    let lexed = lex(source);
    let mask = test_token_mask(&lexed.tokens);
    let scope = scope_for(path);
    let on = |rule: &str| active.is_empty() || active.contains(&rule);

    let mut raw: Vec<Diagnostic> = Vec::new();
    let toks = &lexed.tokens;

    for i in 0..toks.len() {
        let t = &toks[i];
        // Seam rules skip test code (wall time, blocking baselines and
        // unwraps are fine there); the sleep rule is test code's own
        // budget and must NOT skip it — in `rust/tests/` every sleep
        // lives inside a `#[test]` fn.
        let prod = !mask[i];

        if prod && scope.clock_seam && on(CLOCK_SEAM) {
            if (t.is_ident("Instant") || t.is_ident("SystemTime"))
                && seq(toks, i + 1, &[":", ":", "now", "("])
            {
                raw.push(diag(path, t, CLOCK_SEAM, format!(
                    "{}::now() bypasses the injected Clock (util::clock); thread a `Clock` \
                     through and read `now_ns()`",
                    ident_text(t)
                )));
            }
            if t.is_ident("thread") && seq(toks, i + 1, &[":", ":", "sleep"]) {
                raw.push(diag(
                    path,
                    t,
                    CLOCK_SEAM,
                    "thread::sleep in production code: deadlines and backoff must be \
                     driven by the injected Clock"
                        .to_string(),
                ));
            }
        }

        if prod && scope.ticket_seam && on(TICKET_SEAM) && t.is_punct('.') {
            // `.eval(` with a pool-ish receiver: `pool`, `svc`, `service`
            // idents or a `pool()` call directly before the dot.
            if seq(toks, i + 1, &["eval", "("]) {
                let recv_ident = i
                    .checked_sub(1)
                    .map(|p| {
                        toks[p].is_ident("pool")
                            || toks[p].is_ident("svc")
                            || toks[p].is_ident("service")
                    })
                    .unwrap_or(false);
                let recv_call = i >= 3
                    && toks[i - 1].is_punct(')')
                    && toks[i - 2].is_punct('(')
                    && toks[i - 3].is_ident("pool");
                if recv_ident || recv_call {
                    raw.push(diag(
                        path,
                        &toks[i + 1],
                        TICKET_SEAM,
                        "blocking eval on the pool/service outside the adapter: issue a \
                         ticket via submit(..) and redeem it with wait(..)"
                            .to_string(),
                    ));
                }
            }
            if seq(toks, i + 1, &["eval_typed", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 1],
                    TICKET_SEAM,
                    "blocking eval_typed outside the adapter: issue a ticket via \
                     submit_typed(..) and redeem it with wait_typed(..)"
                        .to_string(),
                ));
            }
        }

        if scope.sleep_rule
            && on(NO_SLEEP_IN_TESTS)
            && t.is_ident("thread")
            && seq(toks, i + 1, &[":", ":", "sleep", "("])
        {
            if let Some(d) = audit_sleep(path, toks, i) {
                raw.push(d);
            }
        }

        if prod && scope.panic_free && on(PANIC_FREE_WORKERS) {
            if t.is_punct('.') && seq(toks, i + 1, &["unwrap", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 1],
                    PANIC_FREE_WORKERS,
                    "unwrap() on a worker path: return a typed ServiceError (or use \
                     lock_recover) — a panicking worker strands every client of its shard"
                        .to_string(),
                ));
            }
            if t.is_punct('.') && seq(toks, i + 1, &["expect", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 1],
                    PANIC_FREE_WORKERS,
                    "expect() on a worker path: return a typed ServiceError — a panicking \
                     worker strands every client of its shard"
                        .to_string(),
                ));
            }
            if t.is_ident("panic") && toks.get(i + 1).is_some_and(|n| n.is_punct('!')) {
                raw.push(diag(
                    path,
                    t,
                    PANIC_FREE_WORKERS,
                    "panic! on a worker path: answer with a typed ServiceError instead"
                        .to_string(),
                ));
            }
        }

        if prod
            && scope.mutex_rule
            && on(MUTEX_DISCIPLINE)
            && t.is_punct('.')
            && seq(toks, i + 1, &["lock", "(", ")", "."])
        {
            if seq(toks, i + 5, &["unwrap", "("]) || seq(toks, i + 5, &["expect", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 5],
                    MUTEX_DISCIPLINE,
                    "raw .lock().unwrap(): use util::sync::lock_recover so a poisoned mutex \
                     recovers instead of cascading the panic"
                        .to_string(),
                ));
            } else if seq(toks, i + 5, &["unwrap_or_else", "("]) {
                raw.push(diag(
                    path,
                    &toks[i + 5],
                    MUTEX_DISCIPLINE,
                    "inline .lock().unwrap_or_else(..): poison recovery has exactly one \
                     spelling — util::sync::lock_recover"
                        .to_string(),
                ));
            }
        }
    }

    // Flow rules: intra-procedural dataflow over recovered functions.
    let mut lock_edges = Vec::new();
    if (scope.lock_order && on(LOCK_ORDER))
        || (scope.ticket_leak && on(TICKET_LEAK))
        || (scope.trace_ordering && on(TRACE_ORDERING))
        || (scope.clock_taint && on(CLOCK_TAINT))
    {
        let fns = functions(toks);
        for (fi, f) in fns.iter().enumerate() {
            let Some((open, close)) = f.body else { continue };
            if mask.get(open).copied().unwrap_or(false) {
                continue; // test-only function
            }
            // Tokens of nested fns belong to their own analysis.
            let live = live_tokens(toks, &mask, &fns, fi, (open, close));
            let interior = (open + 1, close);

            if scope.trace_ordering && on(TRACE_ORDERING) {
                trace_ordering_rule(path, toks, &live, interior, &mut raw);
            }
            if scope.lock_order && on(LOCK_ORDER) {
                lock_order_edges(path, toks, &live, f, interior, &mut lock_edges);
            }
            if (scope.ticket_leak && on(TICKET_LEAK))
                || (scope.clock_taint && on(CLOCK_TAINT))
            {
                let binds: Vec<Binding> = bindings(toks, interior)
                    .into_iter()
                    .filter(|b| live[b.name_idx - interior.0 + 1])
                    .collect();
                let calls = CallIndex::build(toks, interior);
                if scope.ticket_leak && on(TICKET_LEAK) {
                    ticket_leak_rule(path, toks, &live, interior, &binds, &calls, &mut raw);
                }
                if scope.clock_taint && on(CLOCK_TAINT) {
                    clock_taint_rule(path, toks, &live, interior, &binds, &calls, &mut raw);
                }
            }
        }
    }

    let allows = parse_allows(&lexed.comments);
    let diags = apply_allows(path, raw, &allows, active);
    let lock_edges = lock_edges
        .into_iter()
        .filter(|e| {
            !allows.iter().any(|a| {
                a.justified
                    && a.rule == LOCK_ORDER
                    && (a.line == e.line || a.line + 1 == e.line)
            })
        })
        .collect();
    FileAnalysis { diags, lock_edges }
}

/// Token liveness for one function: inside the body, not test-masked,
/// not part of a nested fn item.  Indexed as `live[idx - body.0]`.
fn live_tokens(
    toks: &[Token],
    mask: &[bool],
    fns: &[FnInfo],
    fi: usize,
    body: (usize, usize),
) -> Vec<bool> {
    let (open, close) = body;
    let mut live: Vec<bool> = (open..=close)
        .map(|k| !mask.get(k).copied().unwrap_or(false))
        .collect();
    for (gi, g) in fns.iter().enumerate() {
        if gi == fi || g.fn_idx <= open || g.fn_idx >= close {
            continue;
        }
        let end = match g.body {
            Some((_, gc)) => gc,
            None => statement_end(toks, g.fn_idx, close),
        };
        for k in g.fn_idx..=end.min(close) {
            live[k - open] = false;
        }
    }
    live
}

/// `trace-ordering`: in a function that journals `Submitted`/`Executed`
/// and also sends on a channel, every such record must be followed by a
/// `.send(` — a record after the last send describes an action that was
/// already visible to another thread.
fn trace_ordering_rule(
    path: &str,
    toks: &[Token],
    live: &[bool],
    interior: (usize, usize),
    out: &mut Vec<Diagnostic>,
) {
    let (start, end) = interior;
    let idx_live = |k: usize| live.get(k - start + 1).copied().unwrap_or(false);
    let mut records: Vec<(usize, &'static str)> = Vec::new();
    let mut sends: Vec<usize> = Vec::new();
    for k in start..end {
        if !idx_live(k) {
            continue;
        }
        let t = &toks[k];
        if t.is_ident("send")
            && k >= 1
            && toks[k - 1].is_punct('.')
            && toks.get(k + 1).is_some_and(|n| n.is_punct('('))
        {
            sends.push(k);
        }
        if t.is_ident("record")
            && k >= 2
            && toks[k - 1].is_punct('.')
            && toks[k - 2].is_ident("trace")
            && toks.get(k + 1).is_some_and(|n| n.is_punct('('))
        {
            if let Some(args) = call_args(toks, k) {
                for kind in ["Submitted", "Executed"] {
                    if (args.0..args.1).any(|a| toks[a].is_ident(kind)) {
                        records.push((k, if kind == "Submitted" { "Submitted" } else { "Executed" }));
                    }
                }
            }
        }
    }
    if sends.is_empty() {
        return;
    }
    for (rk, kind) in records {
        if !sends.iter().any(|&s| s > rk) {
            out.push(diag(
                path,
                &toks[rk],
                TRACE_ORDERING,
                format!(
                    "TraceKind::{kind} journaled after every channel send in this \
                     function: the trace record must precede the send it describes so \
                     the journal keeps its causal-ordering contract"
                ),
            ));
        }
    }
}

/// Collect lock-acquisition-order edges for one function.  An
/// acquisition is `lock_recover(&path)` or `recv.lock()`; its guard is
/// live to the end of the enclosing block when `let`-bound (ended early
/// by `drop(guard)`), to the end of its statement otherwise.
fn lock_order_edges(
    path: &str,
    toks: &[Token],
    live: &[bool],
    f: &FnInfo,
    interior: (usize, usize),
    out: &mut Vec<LockEdge>,
) {
    let (start, end) = interior;
    let body = f.body.expect("caller checked");
    let idx_live = |k: usize| live.get(k - start + 1).copied().unwrap_or(false);
    let binds = bindings(toks, interior);

    struct Acq {
        idx: usize,
        key: String,
        live_end: usize,
    }
    let mut acqs: Vec<Acq> = Vec::new();
    for k in start..end {
        if !idx_live(k) {
            continue;
        }
        let t = &toks[k];
        let key = if t.is_ident("lock_recover")
            && toks.get(k + 1).is_some_and(|n| n.is_punct('('))
        {
            call_args(toks, k).and_then(|args| last_path_ident(toks, args))
        } else if t.is_ident("lock")
            && k >= 2
            && toks[k - 1].is_punct('.')
            && toks.get(k + 1).is_some_and(|n| n.is_punct('('))
        {
            match &toks[k - 2].kind {
                TokKind::Ident(s) => Some(s.clone()),
                _ => None,
            }
        } else {
            None
        };
        let Some(key) = key else { continue };

        // Guard lifetime: `let`-bound guards live to the end of the
        // enclosing block (or an explicit drop of the binding);
        // temporaries die with their statement.
        let owner = binds.iter().find(|b| b.init.0 <= k && k < b.init.1);
        let live_end = match owner {
            Some(b) => {
                let block_end = enclosing_block_close(toks, body, k);
                uses_of(toks, (b.stmt_end, block_end), &b.name, b.stmt_end)
                    .into_iter()
                    .find(|&u| {
                        u >= 2 && toks[u - 2].is_ident("drop") && toks[u - 1].is_punct('(')
                    })
                    .unwrap_or(block_end)
            }
            None => statement_end(toks, k, end),
        };
        acqs.push(Acq { idx: k, key, live_end });
    }

    for a in 0..acqs.len() {
        for b in (a + 1)..acqs.len() {
            if acqs[b].idx <= acqs[a].live_end && acqs[a].key != acqs[b].key {
                let site = &toks[acqs[b].idx];
                out.push(LockEdge {
                    held: acqs[a].key.clone(),
                    acquired: acqs[b].key.clone(),
                    path: path.to_string(),
                    line: site.line,
                    col: site.col,
                    held_line: toks[acqs[a].idx].line,
                });
            }
        }
    }
}

/// Detect cycles in a lock-order edge set: every edge whose `acquired`
/// lock can reach its `held` lock through other edges is part of a
/// cycle and gets a diagnostic naming the witness site that closes it.
pub fn lock_cycles(edges: &[LockEdge]) -> Vec<Diagnostic> {
    let mut out: Vec<Diagnostic> = Vec::new();
    let mut seen: Vec<(String, u32, u32, String, String)> = Vec::new();
    for e in edges {
        // BFS from e.acquired over held→acquired edges, looking for
        // e.held; remember the edge that reaches it as the witness.
        let mut frontier: Vec<&str> = vec![e.acquired.as_str()];
        let mut visited: Vec<&str> = vec![e.acquired.as_str()];
        let mut witness: Option<&LockEdge> = None;
        'bfs: while let Some(u) = frontier.pop() {
            for w in edges {
                if w.held == u {
                    if w.acquired == e.held {
                        witness = Some(w);
                        break 'bfs;
                    }
                    if !visited.contains(&w.acquired.as_str()) {
                        visited.push(w.acquired.as_str());
                        frontier.push(w.acquired.as_str());
                    }
                }
            }
        }
        if let Some(w) = witness {
            let dedup = (
                e.path.clone(),
                e.line,
                e.col,
                e.held.clone(),
                e.acquired.clone(),
            );
            if seen.contains(&dedup) {
                continue;
            }
            seen.push(dedup);
            out.push(Diagnostic {
                path: e.path.clone(),
                line: e.line,
                col: e.col,
                rule: LOCK_ORDER,
                message: format!(
                    "acquiring `{}` while holding `{}` (held since line {}) forms a \
                     lock-order cycle: `{}` is acquired under `{}` at {}:{} — pick one \
                     global order",
                    e.acquired, e.held, e.held_line, w.acquired, w.held, w.path, w.line
                ),
            });
        }
    }
    out
}

/// `ticket-leak`: every `let`-bound value from a `submit*` call must
/// flow into `wait()`/`collect()`, escape the function (returned,
/// passed on, matched), or be stored in a container that itself reaches
/// a collector or escapes.
fn ticket_leak_rule(
    path: &str,
    toks: &[Token],
    live: &[bool],
    interior: (usize, usize),
    binds: &[Binding],
    calls: &CallIndex,
    out: &mut Vec<Diagnostic>,
) {
    let (start, end) = interior;
    let idx_live = |k: usize| live.get(k - start + 1).copied().unwrap_or(false);
    let last_semi = last_top_level_semi(toks, interior);

    // Tracked tickets: (binding, origin diag site index).  Aliases
    // (`let u = t;`) join the worklist with their own def site.
    let mut tickets: Vec<&Binding> = binds
        .iter()
        .filter(|b| find_call(toks, b.init, SUBMITTERS).is_some())
        .collect();
    // Resolve aliases up front: an init that is exactly one identifier
    // naming a tracked ticket makes the new binding a ticket too.
    loop {
        let mut grew = false;
        for b in binds.iter() {
            if tickets.iter().any(|t| t.name_idx == b.name_idx) {
                continue;
            }
            if b.init.1 == b.init.0 + 1 {
                if let TokKind::Ident(src) = &toks[b.init.0].kind {
                    if tickets.iter().any(|t| &t.name == src) {
                        tickets.push(b);
                        grew = true;
                    }
                }
            }
        }
        if !grew {
            break;
        }
    }

    struct TicketStatus<'a> {
        b: &'a Binding,
        satisfied: bool,
        stored_in: Option<(String, usize)>,
    }
    let mut status: Vec<TicketStatus> = Vec::new();
    for &b in &tickets {
        let uses: Vec<usize> = uses_of(toks, interior, &b.name, b.stmt_end)
            .into_iter()
            .filter(|&k| idx_live(k))
            .collect();
        let mut satisfied = false;
        let mut stored_in: Option<(String, usize)> = None;
        for &k in &uses {
            match classify_use(toks, calls, k, last_semi, end) {
                UseKind::Collected | UseKind::Escaped => {
                    satisfied = true;
                    break;
                }
                UseKind::Stored(container) => {
                    stored_in = Some((container, k));
                }
                UseKind::Neutral => {}
            }
        }
        if !satisfied {
            if let Some((container, taint_idx)) = &stored_in {
                if container_satisfied(toks, calls, live, interior, container, *taint_idx) {
                    satisfied = true;
                }
            }
        }
        status.push(TicketStatus { b, satisfied, stored_in });
    }

    // Alias discharge, to fixpoint: `let moved = t;` hands t's obligation
    // to `moved` — a satisfied alias satisfies its source (and chains of
    // aliases resolve in as many passes as they are deep).
    loop {
        let mut changed = false;
        for i in 0..status.len() {
            if status[i].satisfied {
                continue;
            }
            let name = status[i].b.name.clone();
            let discharged = status.iter().any(|o| {
                o.satisfied
                    && o.b.init.1 == o.b.init.0 + 1
                    && toks[o.b.init.0].is_ident(&name)
            });
            if discharged {
                status[i].satisfied = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    for st in &status {
        if st.satisfied {
            continue;
        }
        if let Some((container, _)) = &st.stored_in {
            out.push(diag(
                path,
                &toks[st.b.name_idx],
                TICKET_LEAK,
                format!(
                    "ticket `{}` is stored in `{container}` which never reaches \
                     wait()/collect(): stored-and-forgotten tickets abandon \
                     in-flight work",
                    st.b.name
                ),
            ));
        } else {
            out.push(diag(
                path,
                &toks[st.b.name_idx],
                TICKET_LEAK,
                format!(
                    "ticket `{}` from {}() is never redeemed with wait()/collect() and \
                     never escapes this function: the submitted work is abandoned",
                    st.b.name,
                    submitter_name(toks, st.b.init)
                ),
            ));
        }
    }
}

fn submitter_name(toks: &[Token], init: (usize, usize)) -> &str {
    find_call(toks, init, SUBMITTERS)
        .and_then(|k| match &toks[k].kind {
            TokKind::Ident(s) => Some(s.as_str()),
            _ => None,
        })
        .unwrap_or("submit")
}

enum UseKind {
    Collected,
    Escaped,
    Stored(String),
    Neutral,
}

/// Classify one use of a tracked value at token `k`.
fn classify_use(
    toks: &[Token],
    calls: &CallIndex,
    k: usize,
    last_semi: Option<usize>,
    body_end: usize,
) -> UseKind {
    // Receiver of a collector method: `t.collect()` style (rare but
    // cheap to honor).
    if toks.get(k + 1).is_some_and(|n| n.is_punct('.')) {
        if let Some(TokKind::Ident(m)) = toks.get(k + 2).map(|t| &t.kind) {
            if COLLECTORS.contains(&m.as_str())
                && toks.get(k + 3).is_some_and(|n| n.is_punct('('))
            {
                return UseKind::Collected;
            }
        }
    }
    let chain = calls.call_chain(k);
    if let Some(&innermost) = chain.first() {
        if COLLECTORS.contains(&innermost) {
            return UseKind::Collected;
        }
        // A collector anywhere up the chain also counts:
        // `wait(wrap(t))` is still a flow into wait.
        if chain.iter().any(|c| COLLECTORS.contains(c)) {
            return UseKind::Collected;
        }
        if CONTAINER_STORES.contains(&innermost) {
            // Find the callee token to identify the receiver; the
            // chain gives the name, re-locate it by walking back from
            // `k` to the nearest matching `name (` opener.
            if let Some(recv) = receiver_of_innermost_call(toks, k, innermost) {
                return UseKind::Stored(recv);
            }
            return UseKind::Escaped; // stored into a non-ident receiver
        }
        if innermost == "drop" {
            return UseKind::Neutral; // an undropped obligation
        }
        return UseKind::Escaped; // any other call consumes the value
    }
    // No enclosing call: moves via match/for/return, or the trailing
    // expression, all count as escapes.
    if let Some(p) = k.checked_sub(1) {
        let t = &toks[p];
        if t.is_ident("match") || t.is_ident("in") || t.is_ident("return") {
            return UseKind::Escaped;
        }
        // Match-arm result: `=> t`.
        if t.is_punct('>') && p >= 1 && toks[p - 1].is_punct('=') {
            return UseKind::Escaped;
        }
    }
    if last_semi.map(|s| k > s).unwrap_or(true) && k < body_end {
        return UseKind::Escaped; // trailing expression
    }
    UseKind::Neutral
}

/// Walk back from use `k` to the opening `name (` of its innermost
/// named call (continuing outward past anonymous tuple/grouping parens)
/// and return the method receiver's trailing identifier.
fn receiver_of_innermost_call(toks: &[Token], k: usize, name: &str) -> Option<String> {
    let mut depth = 0i64;
    let mut j = k;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        if t.is_punct(')') {
            depth += 1;
        } else if t.is_punct('(') {
            if depth == 0 {
                if j >= 1 && toks[j - 1].is_ident(name) {
                    return method_receiver(toks, j - 1);
                }
                // Anonymous group or another callee: keep walking out.
            } else {
                depth -= 1;
            }
        }
    }
    None
}

/// A ticket container is satisfied when it reaches a collector, is
/// consumed by iteration with a collector later in the function, or
/// escapes (returned, passed on, matched, trailing expression).
fn container_satisfied(
    toks: &[Token],
    calls: &CallIndex,
    live: &[bool],
    interior: (usize, usize),
    container: &str,
    taint_idx: usize,
) -> bool {
    let (start, end) = interior;
    let idx_live = |k: usize| live.get(k - start + 1).copied().unwrap_or(false);
    let last_semi = last_top_level_semi(toks, interior);
    let collector_after = |k: usize| has_real_collector(toks, (k, end));
    for k in uses_of(toks, interior, container, taint_idx) {
        if !idx_live(k) {
            continue;
        }
        match classify_use(toks, calls, k, last_semi, end) {
            UseKind::Collected => return true,
            UseKind::Escaped => {
                // `for t in container` / `container.drain(..)` style
                // consumption only discharges the obligation when a
                // collector actually runs on what comes out.
                let iterated = k
                    .checked_sub(1)
                    .is_some_and(|p| toks[p].is_ident("in"));
                if !iterated || collector_after(k) {
                    return true;
                }
            }
            UseKind::Stored(_) | UseKind::Neutral => {
                // `container.drain(..)` as a receiver shows up as the use
                // being followed by `.drain(` — treat any receiver use
                // followed by an iterator-ish consumption as iteration.
                if toks.get(k + 1).is_some_and(|n| n.is_punct('.'))
                    && matches!(
                        toks.get(k + 2).map(|t| &t.kind),
                        Some(TokKind::Ident(m)) if m == "drain" || m == "into_iter" || m == "iter"
                    )
                    && collector_after(k)
                {
                    return true;
                }
            }
        }
    }
    false
}

/// Is there a *redeeming* collector call in `range`?  Iterator
/// `.collect()` / `.collect::<T>()` has an empty argument list and is
/// excluded; `wait(t)` / `collect(ticket)` have arguments.
fn has_real_collector(toks: &[Token], range: (usize, usize)) -> bool {
    let (start, end) = range;
    for k in start..end.min(toks.len()) {
        if COLLECTORS.iter().any(|c| toks[k].is_ident(c)) {
            if let Some((a0, a1)) = call_args(toks, k) {
                if a1 > a0 {
                    return true;
                }
            }
        }
    }
    false
}

/// Token index of the last `;` at statement level of the function body
/// (depth 0 relative to the interior).  Uses after it are in the
/// trailing expression.
fn last_top_level_semi(toks: &[Token], interior: (usize, usize)) -> Option<usize> {
    let (start, end) = interior;
    let mut depth = 0i64;
    let mut last = None;
    for k in start..end.min(toks.len()) {
        let t = &toks[k];
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
        } else if t.is_punct(';') && depth == 0 {
            last = Some(k);
        }
    }
    last
}

/// `clock-taint`: taint `let` bindings whose initializer reads wall
/// time (directly or through another tainted binding) and flag any
/// tainted value reaching deadline arithmetic — a call whose name
/// mentions deadlines/timeouts (or `wait_budget`), or a binding whose
/// own name says it is a deadline.
fn clock_taint_rule(
    path: &str,
    toks: &[Token],
    live: &[bool],
    interior: (usize, usize),
    binds: &[Binding],
    calls: &CallIndex,
    out: &mut Vec<Diagnostic>,
) {
    let (start, end) = interior;
    let idx_live = |k: usize| live.get(k - start + 1).copied().unwrap_or(false);

    let wall_source = |range: (usize, usize)| -> bool {
        for k in range.0..range.1.min(toks.len()) {
            if (toks[k].is_ident("Instant") || toks[k].is_ident("SystemTime"))
                && seq(toks, k + 1, &[":", ":", "now", "("])
            {
                return true;
            }
            if toks[k].is_ident("elapsed")
                && k >= 1
                && toks[k - 1].is_punct('.')
                && toks.get(k + 1).is_some_and(|n| n.is_punct('('))
            {
                return true;
            }
        }
        false
    };

    let mut tainted: Vec<&Binding> = Vec::new();
    for b in binds {
        let direct = wall_source(b.init);
        let via = tainted.iter().any(|t| {
            !uses_of(toks, b.init, &t.name, b.init.0.saturating_sub(1)).is_empty()
        });
        if direct || via {
            tainted.push(b);
            if is_deadline_name(&b.name) {
                out.push(diag(
                    path,
                    &toks[b.name_idx],
                    CLOCK_TAINT,
                    format!(
                        "`{}` is wall-time-derived: deadlines must be computed from the \
                         injected Clock's now_ns(), not Instant/SystemTime/elapsed()",
                        b.name
                    ),
                ));
            }
        }
    }

    for b in &tainted {
        for k in uses_of(toks, interior, &b.name, b.stmt_end) {
            if !idx_live(k) {
                continue;
            }
            if let Some(sink) = calls
                .call_chain(k)
                .into_iter()
                .find(|c| *c == "wait_budget" || is_deadline_name(c))
            {
                out.push(diag(
                    path,
                    &toks[k],
                    CLOCK_TAINT,
                    format!(
                        "wall-time-derived `{}` flows into `{sink}(..)`: deadline \
                         arithmetic must read the injected Clock (util::clock), not \
                         Instant/SystemTime/elapsed()",
                        b.name
                    ),
                ));
            }
        }
    }
}

fn is_deadline_name(name: &str) -> bool {
    let lower = name.to_ascii_lowercase();
    lower.contains("deadline") || lower.contains("timeout")
}

fn ident_text(t: &Token) -> &str {
    match &t.kind {
        TokKind::Ident(i) => i,
        _ => "",
    }
}

fn diag(path: &str, at: &Token, rule: &'static str, message: String) -> Diagnostic {
    Diagnostic { path: path.to_string(), line: at.line, col: at.col, rule, message }
}

/// Match a sequence of idents / single-char puncts starting at `from`.
fn seq(toks: &[Token], from: usize, pat: &[&str]) -> bool {
    if from + pat.len() > toks.len() {
        return false;
    }
    pat.iter().enumerate().all(|(k, p)| {
        let t = &toks[from + k];
        let mut chars = p.chars();
        match (chars.next(), chars.next()) {
            (Some(c), None) if !c.is_alphanumeric() && c != '_' => t.is_punct(c),
            _ => t.is_ident(p),
        }
    })
}

/// Audit one `thread::sleep(` call in a test (token `i` = `thread`).
/// Auditable form: `thread::sleep([std::[time::]]Duration::from_X(<literal>))`.
/// Returns a diagnostic for an over-budget or non-literal duration.
fn audit_sleep(path: &str, toks: &[Token], i: usize) -> Option<Diagnostic> {
    // Argument tokens: from after `(` to its matching `)`.
    let open = i + 4;
    let mut depth = 0usize;
    let mut close = open;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                close = k;
                break;
            }
        }
    }
    if close <= open {
        // Unterminated call (malformed source): rustc owns that error.
        return None;
    }
    let arg = &toks[open + 1..close];

    // Strip an optional `std::` / `std::time::` path prefix.
    let mut a = arg;
    for prefix in ["std", "time"] {
        if a.first().is_some_and(|t| t.is_ident(prefix))
            && a.get(1).is_some_and(|t| t.is_punct(':'))
            && a.get(2).is_some_and(|t| t.is_punct(':'))
        {
            a = &a[3..];
        }
    }

    let auditable = a.len() == 7
        && a[0].is_ident("Duration")
        && a[1].is_punct(':')
        && a[2].is_punct(':')
        && matches!(a[3].kind, TokKind::Ident(_))
        && a[4].is_punct('(')
        && matches!(a[5].kind, TokKind::Num(_))
        && a[6].is_punct(')');
    if !auditable {
        return Some(diag(
            path,
            &toks[i],
            NO_SLEEP_IN_TESTS,
            "unauditable sleep duration (not a literal Duration::from_*): drive timing \
             through ManualClock or testbed::wait_until"
                .to_string(),
        ));
    }

    let ctor = ident_text(&a[3]).to_string();
    let raw = match &a[5].kind {
        TokKind::Num(n) => n.replace('_', ""),
        _ => return None,
    };
    // Strip a numeric suffix (u64, f32...) if present.
    let numeric: String = raw
        .chars()
        .take_while(|c| c.is_ascii_digit() || *c == '.')
        .collect();
    let value: f64 = match numeric.parse() {
        Ok(v) => v,
        Err(_) => {
            return Some(diag(
                path,
                &toks[i],
                NO_SLEEP_IN_TESTS,
                format!("unauditable sleep duration literal `{raw}`"),
            ))
        }
    };
    let ms = match ctor.as_str() {
        "from_millis" => value,
        "from_secs" => value * 1000.0,
        "from_secs_f32" | "from_secs_f64" => value * 1000.0,
        "from_micros" => value / 1000.0,
        "from_nanos" => value / 1_000_000.0,
        _ => {
            return Some(diag(
                path,
                &toks[i],
                NO_SLEEP_IN_TESTS,
                format!("unauditable sleep duration constructor `Duration::{ctor}`"),
            ))
        }
    };
    if ms > SLEEP_LIMIT_MS {
        return Some(diag(
            path,
            &toks[i],
            NO_SLEEP_IN_TESTS,
            format!(
                "sleep of {ms:.0} ms exceeds the {SLEEP_LIMIT_MS:.0} ms budget: drive \
                 timing through ManualClock or testbed::wait_until"
            ),
        ));
    }
    None
}

/// One parsed `axdt-lint: allow(<rule>)` suppression.
struct Allow {
    rule: String,
    justified: bool,
    line: u32,
    col: u32,
}

fn parse_allows(comments: &[Comment]) -> Vec<Allow> {
    let mut out = Vec::new();
    for c in comments {
        let mut rest = c.text.as_str();
        while let Some(pos) = rest.find("axdt-lint:") {
            rest = &rest[pos + "axdt-lint:".len()..];
            let Some(ap) = rest.find("allow(") else { continue };
            let after = &rest[ap + "allow(".len()..];
            let Some(cp) = after.find(')') else { continue };
            let rule = after[..cp].trim().to_string();
            // Justification: any non-empty text after the `)`, with
            // leading separator punctuation stripped.
            let tail = after[cp + 1..]
                .trim_start_matches(&[':', '-', '—', ' ', '\t'][..])
                .trim();
            out.push(Allow {
                rule,
                justified: !tail.is_empty(),
                line: c.line,
                col: c.col,
            });
            rest = &after[cp + 1..];
        }
    }
    out
}

/// Filter diagnostics through suppression comments and append `bad-allow`
/// findings for malformed ones.
fn apply_allows(
    path: &str,
    raw: Vec<Diagnostic>,
    allows: &[Allow],
    active: &[&str],
) -> Vec<Diagnostic> {
    let on = |rule: &str| active.is_empty() || active.contains(&rule);
    let known = rule_ids();

    let mut out: Vec<Diagnostic> = raw
        .into_iter()
        .filter(|d| {
            !allows.iter().any(|a| {
                a.justified
                    && a.rule == d.rule
                    && (a.line == d.line || a.line + 1 == d.line)
            })
        })
        .collect();

    for a in allows {
        if !known.contains(&a.rule.as_str()) {
            // Unknown rule ids only fail full runs: a partial run cannot
            // tell a typo from a rule it was asked not to load.
            if active.is_empty() {
                out.push(Diagnostic {
                    path: path.to_string(),
                    line: a.line,
                    col: a.col,
                    rule: BAD_ALLOW,
                    message: format!("allow names unknown rule `{}`", a.rule),
                });
            }
        } else if !a.justified && on(a.rule.as_str()) {
            out.push(Diagnostic {
                path: path.to_string(),
                line: a.line,
                col: a.col,
                rule: BAD_ALLOW,
                message: format!(
                    "allow({}) without a justification is ignored: write \
                     `// axdt-lint: allow({}): <why this exception is sound>`",
                    a.rule, a.rule
                ),
            });
        }
    }

    out.sort_by(|a, b| {
        (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule))
    });
    out
}
