//! A small hand-rolled Rust lexer: just enough tokenization for
//! token-sequence lints, with the properties the grep guards it replaces
//! could never have:
//!
//! * line (`//`) and block (`/* */`, nesting) comments are skipped — a
//!   comment *talking about* `Instant::now()` can never fire a rule —
//!   but retained with positions, so `// axdt-lint: allow(..)`
//!   suppressions can be resolved per line;
//! * string literals (plain, raw `r#".."#`, byte, byte-raw), char and
//!   byte-char literals are skipped, so a diagnostic message mentioning
//!   `.unwrap()` is not a violation;
//! * lifetimes (`'a`) are distinguished from char literals;
//! * numeric literals keep their raw text, so duration arguments can be
//!   audited (`no-sleep-in-tests`).
//!
//! The lexer does NOT parse Rust. Rules match short token sequences
//! (`Instant :: now (`, `. lock ( ) . unwrap (`), which is exactly the
//! granularity the architectural seams are defined at.

/// One lexed token with its 1-based source position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub kind: TokKind,
    pub line: u32,
    pub col: u32,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    Ident(String),
    /// Raw literal text, underscores and suffix included (`150_000`,
    /// `2.5`, `0xff`).
    Num(String),
    Punct(char),
    /// String / char-ish literal (content deliberately discarded).
    Lit,
    Lifetime,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(&self.kind, TokKind::Ident(i) if i == s)
    }

    pub fn is_punct(&self, c: char) -> bool {
        matches!(&self.kind, TokKind::Punct(p) if *p == c)
    }
}

/// A comment with the 1-based line it starts on (block comments may span
/// further; suppressions are resolved against the start line).
#[derive(Debug, Clone)]
pub struct Comment {
    pub text: String,
    pub line: u32,
    pub col: u32,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

struct Cursor {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn new(src: &str) -> Self {
        Cursor { chars: src.chars().collect(), pos: 0, line: 1, col: 1 }
    }

    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn eof(&self) -> bool {
        self.pos >= self.chars.len()
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Never fails: unterminated literals simply consume the
/// rest of the file (the linter's job is seam rules, not syntax errors —
/// rustc owns those).
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor::new(src);
    let mut out = Lexed::default();

    while !cur.eof() {
        let (line, col) = (cur.line, cur.col);
        let c = match cur.peek(0) {
            Some(c) => c,
            None => break,
        };

        // Whitespace.
        if c.is_whitespace() {
            cur.bump();
            continue;
        }

        // Comments.
        if c == '/' && cur.peek(1) == Some('/') {
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            out.comments.push(Comment { text, line, col });
            continue;
        }
        if c == '/' && cur.peek(1) == Some('*') {
            let mut text = String::new();
            let mut depth = 0usize;
            while let Some(ch) = cur.peek(0) {
                if ch == '/' && cur.peek(1) == Some('*') {
                    depth += 1;
                    text.push_str("/*");
                    cur.bump();
                    cur.bump();
                } else if ch == '*' && cur.peek(1) == Some('/') {
                    depth -= 1;
                    text.push_str("*/");
                    cur.bump();
                    cur.bump();
                    if depth == 0 {
                        break;
                    }
                } else {
                    text.push(ch);
                    cur.bump();
                }
            }
            out.comments.push(Comment { text, line, col });
            continue;
        }

        // Raw strings / byte strings / raw identifiers: r"..", r#".."#,
        // br".." etc.  `r` or `br` followed by `"` or `#..#"` is a raw
        // string; `r#ident` is a raw identifier.
        if c == 'r' || (c == 'b' && matches!(cur.peek(1), Some('r'))) {
            let prefix_len = if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while cur.peek(prefix_len + hashes) == Some('#') {
                hashes += 1;
            }
            match cur.peek(prefix_len + hashes) {
                Some('"') => {
                    for _ in 0..prefix_len + hashes + 1 {
                        cur.bump();
                    }
                    // Consume until `"` followed by `hashes` hashes.
                    'raw: while let Some(ch) = cur.bump() {
                        if ch == '"' {
                            for h in 0..hashes {
                                if cur.peek(h) != Some('#') {
                                    continue 'raw;
                                }
                            }
                            for _ in 0..hashes {
                                cur.bump();
                            }
                            break;
                        }
                    }
                    out.tokens.push(Token { kind: TokKind::Lit, line, col });
                    continue;
                }
                Some(ch) if hashes > 0 && is_ident_start(ch) => {
                    // Raw identifier r#type.
                    for _ in 0..prefix_len + hashes {
                        cur.bump();
                    }
                    let mut ident = String::new();
                    while let Some(ch) = cur.peek(0) {
                        if !is_ident_continue(ch) {
                            break;
                        }
                        ident.push(ch);
                        cur.bump();
                    }
                    out.tokens.push(Token { kind: TokKind::Ident(ident), line, col });
                    continue;
                }
                _ => {} // plain identifier starting with r/b: fall through
            }
        }

        // Byte strings / byte chars: b"..", b'.'.
        if c == 'b' && matches!(cur.peek(1), Some('"') | Some('\'')) {
            cur.bump(); // b
            let quote = cur.bump().unwrap_or('"');
            consume_quoted(&mut cur, quote);
            out.tokens.push(Token { kind: TokKind::Lit, line, col });
            continue;
        }

        // Plain strings.
        if c == '"' {
            cur.bump();
            consume_quoted(&mut cur, '"');
            out.tokens.push(Token { kind: TokKind::Lit, line, col });
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' {
            let next = cur.peek(1);
            let is_char = match next {
                Some('\\') => true,
                Some(n) if n != '\'' => cur.peek(2) == Some('\''),
                _ => true, // '' or '\'': treat as (malformed) char
            };
            if is_char {
                cur.bump();
                consume_quoted(&mut cur, '\'');
                out.tokens.push(Token { kind: TokKind::Lit, line, col });
            } else {
                // Lifetime: consume the quote and the identifier.
                cur.bump();
                while let Some(ch) = cur.peek(0) {
                    if !is_ident_continue(ch) {
                        break;
                    }
                    cur.bump();
                }
                out.tokens.push(Token { kind: TokKind::Lifetime, line, col });
            }
            continue;
        }

        // Numbers (raw text kept for duration auditing).  A trailing
        // `.` is only part of the number when followed by a digit, so
        // ranges (`0..10`) and method calls (`1.to_string()`) stay intact.
        if c.is_ascii_digit() {
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch.is_ascii_alphanumeric() || ch == '_' {
                    text.push(ch);
                    cur.bump();
                } else if ch == '.'
                    && cur.peek(1).is_some_and(|d| d.is_ascii_digit())
                    && !text.contains('.')
                {
                    text.push(ch);
                    cur.bump();
                } else {
                    break;
                }
            }
            out.tokens.push(Token { kind: TokKind::Num(text), line, col });
            continue;
        }

        // Identifiers / keywords.
        if is_ident_start(c) {
            let mut ident = String::new();
            while let Some(ch) = cur.peek(0) {
                if !is_ident_continue(ch) {
                    break;
                }
                ident.push(ch);
                cur.bump();
            }
            out.tokens.push(Token { kind: TokKind::Ident(ident), line, col });
            continue;
        }

        // Everything else: single-char punctuation (`::` is two tokens).
        cur.bump();
        out.tokens.push(Token { kind: TokKind::Punct(c), line, col });
    }

    out
}

/// Consume a quoted literal body up to the closing `quote`, honoring
/// backslash escapes.  The opening quote must already be consumed.
fn consume_quoted(cur: &mut Cursor, quote: char) {
    while let Some(ch) = cur.bump() {
        if ch == '\\' {
            cur.bump();
        } else if ch == quote {
            break;
        }
    }
}

/// Byte-position spans (token indices) of test-only code: any item
/// annotated `#[cfg(test)]` / `#[cfg(all(test, ..))]` / `#[test]`.
/// Seam rules skip tokens inside these spans — test code may use wall
/// time, blocking eval baselines, and unwraps freely.
pub fn test_token_mask(tokens: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if tokens[i].is_punct('#') && i + 1 < tokens.len() && tokens[i + 1].is_punct('[') {
            // Collect the attribute tokens up to the matching `]`.
            let attr_start = i + 2;
            let mut depth = 1usize;
            let mut j = attr_start;
            while j < tokens.len() && depth > 0 {
                if tokens[j].is_punct('[') {
                    depth += 1;
                } else if tokens[j].is_punct(']') {
                    depth -= 1;
                }
                j += 1;
            }
            let attr_end = j.saturating_sub(1).max(attr_start);
            let attr = &tokens[attr_start..attr_end];
            if is_test_attr(attr) {
                // Mark from the attribute through the end of the item it
                // decorates: the next `{..}` block (or a bare `;`) at
                // nesting depth 0.
                let end = item_end(tokens, j);
                for m in mask.iter_mut().take(end).skip(i) {
                    *m = true;
                }
                i = end;
                continue;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    mask
}

/// `#[test]`, `#[cfg(test)]`, `#[cfg(all(test, ..))]` and friends: the
/// attribute body either IS the ident `test` or is a `cfg(..)` whose
/// argument list mentions the ident `test` at any depth — except inside a
/// `not(..)` group, so `#[cfg(not(test))]` code is still linted.
fn is_test_attr(attr: &[Token]) -> bool {
    match attr.first() {
        Some(t) if t.is_ident("test") && attr.len() == 1 => true,
        Some(t) if t.is_ident("cfg") => cfg_mentions_test(&attr[1..]),
        _ => false,
    }
}

fn cfg_mentions_test(args: &[Token]) -> bool {
    let mut depth = 0i64;
    // Paren depths at which a `not(` group is open; `test` under any of
    // them is a negation, not a test gate.
    let mut not_open: Vec<i64> = Vec::new();
    let mut i = 0usize;
    while i < args.len() {
        let t = &args[i];
        if t.is_ident("not") && args.get(i + 1).is_some_and(|n| n.is_punct('(')) {
            not_open.push(depth + 1);
        } else if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            while not_open.last().is_some_and(|&d| d > depth) {
                not_open.pop();
            }
        } else if t.is_ident("test") && not_open.is_empty() {
            return true;
        }
        i += 1;
    }
    false
}

/// Token index one past the end of the item starting at `start` (which
/// points just past the item's attribute).  Skips any further attributes,
/// then consumes to the first top-level `{..}` block's close or a bare
/// `;` — enough for `mod`, `fn`, `struct`, `impl` and `use` items.
fn item_end(tokens: &[Token], mut start: usize) -> usize {
    // Further attributes on the same item.
    while start + 1 < tokens.len()
        && tokens[start].is_punct('#')
        && tokens[start + 1].is_punct('[')
    {
        let mut depth = 0usize;
        let mut j = start + 1;
        while j < tokens.len() {
            if tokens[j].is_punct('[') {
                depth += 1;
            } else if tokens[j].is_punct(']') {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            }
            j += 1;
        }
        start = j;
    }
    let mut i = start;
    let mut paren = 0i64;
    while i < tokens.len() {
        let t = &tokens[i];
        if t.is_punct('(') || t.is_punct('[') {
            paren += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            paren -= 1;
        } else if t.is_punct(';') && paren <= 0 {
            return i + 1;
        } else if t.is_punct('{') && paren <= 0 {
            // Consume the braced body.
            let mut depth = 0usize;
            while i < tokens.len() {
                if tokens[i].is_punct('{') {
                    depth += 1;
                } else if tokens[i].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        return i + 1;
                    }
                }
                i += 1;
            }
            return tokens.len();
        }
        i += 1;
    }
    tokens.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Ident(i) => Some(i),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_and_strings_are_not_tokens() {
        let src = r###"
            // Instant::now() in a comment
            /* thread::sleep in a block /* nested */ comment */
            let s = "Instant::now()";
            let r = r#"pool.eval("x")"#;
            let c = 'x';
            let e = '\n';
            fn f<'a>(x: &'a str) {}
        "###;
        let ids = idents(src);
        assert!(!ids.contains(&"Instant".to_string()));
        assert!(!ids.contains(&"sleep".to_string()));
        assert!(!ids.contains(&"eval".to_string()));
        assert!(ids.contains(&"str".to_string()), "lifetime must not eat the type");
    }

    #[test]
    fn comment_positions_are_recorded() {
        let lexed = lex("let x = 1; // axdt-lint: allow(clock-seam): why\nlet y = 2;\n");
        assert_eq!(lexed.comments.len(), 1);
        assert_eq!(lexed.comments[0].line, 1);
        assert!(lexed.comments[0].text.contains("allow(clock-seam)"));
    }

    #[test]
    fn numbers_keep_raw_text_and_ranges_split() {
        let lexed = lex("from_millis(150_000); for i in 0..10 {} let f = 2.5f64;");
        let nums: Vec<String> = lexed
            .tokens
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Num(n) => Some(n),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec!["150_000", "0", "10", "2.5f64"]);
    }

    #[test]
    fn cfg_test_mask_covers_the_module_body() {
        let src = r#"
            fn prod() { x.unwrap(); }
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() { y.unwrap(); }
            }
            fn prod2() { z.unwrap(); }
        "#;
        let lexed = lex(src);
        let mask = test_token_mask(&lexed.tokens);
        let unwraps: Vec<bool> = lexed
            .tokens
            .iter()
            .zip(&mask)
            .filter(|(t, _)| t.is_ident("unwrap"))
            .map(|(_, &m)| m)
            .collect();
        assert_eq!(unwraps, vec![false, true, false]);
    }

    #[test]
    fn cfg_not_test_is_still_linted() {
        let src = "#[cfg(not(test))]\nfn prod() { x.unwrap(); }\n#[cfg(any(test, feature))]\nfn gated() { y.unwrap(); }\n";
        let lexed = lex(src);
        let mask = test_token_mask(&lexed.tokens);
        let unwraps: Vec<bool> = lexed
            .tokens
            .iter()
            .zip(&mask)
            .filter(|(t, _)| t.is_ident("unwrap"))
            .map(|(_, &m)| m)
            .collect();
        assert_eq!(unwraps, vec![false, true]);
    }

    #[test]
    fn cfg_test_on_semicolon_item_does_not_swallow_the_file() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn prod() { x.unwrap(); }\n";
        let lexed = lex(src);
        let mask = test_token_mask(&lexed.tokens);
        let unwrap_masked = lexed
            .tokens
            .iter()
            .zip(&mask)
            .find(|(t, _)| t.is_ident("unwrap"))
            .map(|(_, &m)| m);
        assert_eq!(unwrap_masked, Some(false));
    }
}
