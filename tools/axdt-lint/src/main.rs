//! CLI for the axdt architectural linter.
//!
//! ```text
//! axdt-lint [--rule <id>]... [--root <dir>] [--format <fmt>] [--list-rules] [FILE]...
//! ```
//!
//! * no args: lint the whole tree (rust/src, rust/tests, rust/benches,
//!   examples, tools) under the repo root found by walking up from the
//!   current directory;
//! * `--rule <id>` (repeatable): run only the named rules;
//! * `--format text|json|sarif`: diagnostic output format — `sarif`
//!   emits SARIF 2.1.0 on stdout for code-scanning upload (exit codes
//!   are unchanged: a SARIF run with findings still exits 1);
//! * `FILE` operands: lint just those files (paths are resolved against
//!   the repo root for rule scoping).
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use axdt_lint::sarif::{to_json, to_sarif};
use axdt_lint::{find_root, lint_path, lint_tree, rule_ids, ALL_RULES};

#[derive(PartialEq)]
enum Format {
    Text,
    Json,
    Sarif,
}

fn main() -> ExitCode {
    let mut rules: Vec<String> = Vec::new();
    let mut root_arg: Option<PathBuf> = None;
    let mut files: Vec<PathBuf> = Vec::new();
    let mut format = Format::Text;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--rule" => match args.next() {
                Some(r) => rules.push(r),
                None => return usage("--rule needs a rule id"),
            },
            "--root" => match args.next() {
                Some(d) => root_arg = Some(PathBuf::from(d)),
                None => return usage("--root needs a directory"),
            },
            "--format" => match args.next().as_deref() {
                Some("text") => format = Format::Text,
                Some("json") => format = Format::Json,
                Some("sarif") => format = Format::Sarif,
                Some(f) => return usage(&format!("unknown format `{f}` (text|json|sarif)")),
                None => return usage("--format needs text|json|sarif"),
            },
            "--list-rules" => {
                for (id, what) in ALL_RULES {
                    println!("{id:<20} {what}");
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!(
                    "usage: axdt-lint [--rule <id>]... [--root <dir>] [--format text|json|sarif] \
                     [--list-rules] [FILE]..."
                );
                return ExitCode::SUCCESS;
            }
            _ if arg.starts_with("--") => {
                return usage(&format!("unknown flag {arg}"));
            }
            _ => files.push(PathBuf::from(arg)),
        }
    }

    let known = rule_ids();
    for r in &rules {
        if !known.contains(&r.as_str()) {
            return usage(&format!(
                "unknown rule `{r}` (known: {})",
                known.join(", ")
            ));
        }
    }
    let active: Vec<&str> = rules.iter().map(|s| s.as_str()).collect();

    let cwd = match std::env::current_dir() {
        Ok(d) => d,
        Err(e) => return fail(&format!("cannot read current dir: {e}")),
    };
    let root = match root_arg.or_else(|| find_root(&cwd)) {
        Some(r) => r,
        None => return fail("no repo root (a directory containing rust/src) above here"),
    };

    let result = if files.is_empty() {
        lint_tree(&root, &active)
    } else {
        let mut out = Vec::new();
        for f in &files {
            let abs = if f.is_absolute() { f.clone() } else { root.join(f) };
            match lint_path(&root, &abs, &active) {
                Ok(d) => out.extend(d),
                Err(e) => return fail(&format!("{}: {e}", f.display())),
            }
        }
        Ok(out)
    };

    match result {
        Ok(diags) => {
            match format {
                Format::Json => print!("{}", to_json(&diags)),
                Format::Sarif => print!("{}", to_sarif(&diags)),
                Format::Text => {}
            }
            if diags.is_empty() {
                if format == Format::Text {
                    let what = if active.is_empty() {
                        "all rules".to_string()
                    } else {
                        active.join(", ")
                    };
                    println!("OK: axdt-lint clean ({what})");
                }
                ExitCode::SUCCESS
            } else {
                if format == Format::Text {
                    for d in &diags {
                        eprintln!("{d}");
                    }
                }
                eprintln!(
                    "axdt-lint: {} violation(s); suppress intentional exceptions with \
                     `// axdt-lint: allow(<rule>): <justification>`",
                    diags.len()
                );
                ExitCode::FAILURE
            }
        }
        Err(e) => fail(&format!("lint walk failed: {e}")),
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("axdt-lint: {msg}");
    eprintln!(
        "usage: axdt-lint [--rule <id>]... [--root <dir>] [--format text|json|sarif] \
         [--list-rules] [FILE]..."
    );
    ExitCode::from(2)
}

fn fail(msg: &str) -> ExitCode {
    eprintln!("axdt-lint: {msg}");
    ExitCode::from(2)
}
