//! Diagnostic emitters: SARIF 2.1.0 (for GitHub code-scanning upload)
//! and a plain JSON array.  Hand-rolled serialization — the linter is
//! zero-dependency by design, and the subset of JSON we emit (strings,
//! integers, fixed object shapes) doesn't justify a serializer.

use crate::rules::{Diagnostic, ALL_RULES, BAD_ALLOW};

/// Escape a string for embedding in a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Plain JSON: an array of `{path, line, col, rule, message}` objects,
/// in input order.
pub fn to_json(diags: &[Diagnostic]) -> String {
    let mut out = String::from("[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"path\": \"{}\", \"line\": {}, \"col\": {}, \"rule\": \"{}\", \
             \"message\": \"{}\"}}",
            esc(&d.path),
            d.line,
            d.col,
            esc(d.rule),
            esc(&d.message)
        ));
    }
    if !diags.is_empty() {
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

/// SARIF 2.1.0: one run, one driver, a rule descriptor per registered
/// rule (plus the `bad-allow` meta-rule) whether or not it fired — the
/// descriptors are the contract code scanning indexes results under.
pub fn to_sarif(diags: &[Diagnostic]) -> String {
    let mut rules = String::new();
    let descriptors: Vec<(&str, &str)> = ALL_RULES
        .iter()
        .copied()
        .chain(std::iter::once((
            BAD_ALLOW,
            "a malformed axdt-lint suppression: missing justification or unknown rule id",
        )))
        .collect();
    for (i, (id, desc)) in descriptors.iter().enumerate() {
        if i > 0 {
            rules.push(',');
        }
        rules.push_str(&format!(
            "\n          {{\n            \"id\": \"{}\",\n            \
             \"shortDescription\": {{\"text\": \"{}\"}},\n            \
             \"defaultConfiguration\": {{\"level\": \"error\"}}\n          }}",
            esc(id),
            esc(desc)
        ));
    }

    let mut results = String::new();
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            results.push(',');
        }
        results.push_str(&format!(
            "\n      {{\n        \"ruleId\": \"{}\",\n        \"level\": \"error\",\n        \
             \"message\": {{\"text\": \"{}\"}},\n        \"locations\": [{{\n          \
             \"physicalLocation\": {{\n            \
             \"artifactLocation\": {{\"uri\": \"{}\"}},\n            \
             \"region\": {{\"startLine\": {}, \"startColumn\": {}}}\n          }}\n        \
             }}]\n      }}",
            esc(d.rule),
            esc(&d.message),
            esc(&d.path),
            d.line,
            d.col
        ));
    }
    if !diags.is_empty() {
        results.push('\n');
        results.push_str("    ");
    }

    format!(
        "{{\n  \"$schema\": \"https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/\
         Schemata/sarif-schema-2.1.0.json\",\n  \"version\": \"2.1.0\",\n  \"runs\": [{{\n    \
         \"tool\": {{\n      \"driver\": {{\n        \"name\": \"axdt-lint\",\n        \
         \"informationUri\": \"https://github.com/axdt/axdt\",\n        \
         \"rules\": [{rules}\n        ]\n      }}\n    }},\n    \
         \"results\": [{results}]\n  }}]\n}}\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Diagnostic> {
        vec![Diagnostic {
            path: "rust/src/x.rs".into(),
            line: 3,
            col: 7,
            rule: crate::rules::CLOCK_SEAM,
            message: "a \"quoted\" message\nwith a newline".into(),
        }]
    }

    #[test]
    fn json_escapes_and_round_trips_shape() {
        let j = to_json(&sample());
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("\\n"));
        assert!(j.trim_start().starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert!(to_json(&[]).trim() == "[]");
    }

    #[test]
    fn sarif_has_descriptor_per_rule_and_result_locations() {
        let s = to_sarif(&sample());
        for (id, _) in ALL_RULES {
            assert!(
                s.contains(&format!("\"id\": \"{id}\"")),
                "missing descriptor for {id}"
            );
        }
        assert!(s.contains("\"id\": \"bad-allow\""));
        assert!(s.contains("\"ruleId\": \"clock-seam\""));
        assert!(s.contains("\"startLine\": 3"));
        assert!(s.contains("\"version\": \"2.1.0\""));
    }
}
