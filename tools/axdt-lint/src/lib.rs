//! axdt-lint: token-level architectural lints for the axdt tree.
//!
//! The codebase has two load-bearing seams — every deadline decision
//! reads the injected `Clock` (util::clock), and every evaluation flows
//! through the two-phase `submit`/`wait` ticket path — plus hard
//! worker-survival rules (typed errors, never panics).  Grep guards
//! cannot see comments, strings, or test regions; this crate lexes every
//! Rust source (no `syn`, zero dependencies, offline-green) and enforces
//! the rule registry in [`rules`] with `file:line:col` diagnostics and
//! justified `// axdt-lint: allow(<rule>): <why>` suppressions.
//!
//! Run it as `cargo run -p axdt-lint` (or `make lint`); CI runs it as a
//! required job, and `scripts/forbid_blocking_eval.sh` /
//! `scripts/forbid_long_sleeps.sh` are thin wrappers over single rules.

pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{lint_source, rule_ids, Diagnostic, ALL_RULES};

/// Directories under the repo root the full-tree lint walks.  Rules are
/// path-scoped (see `rules::scope_for`), so walking a directory no rule
/// targets is free — and keeps future rules one table entry away.
const LINT_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches"];

/// Lint the whole tree under `root` (the repo checkout).  `active` is the
/// rule filter (empty = all rules).  Returns diagnostics sorted by path.
pub fn lint_tree(root: &Path, active: &[&str]) -> io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    for dir in LINT_DIRS {
        let abs = root.join(dir);
        if abs.is_dir() {
            collect_rs(&abs, &mut files)?;
        }
    }
    files.sort();
    let mut out = Vec::new();
    for file in files {
        out.extend(lint_path(root, &file, active)?);
    }
    Ok(out)
}

/// Lint one file, reporting diagnostics under its path relative to
/// `root` (rule scoping runs on that relative path).
pub fn lint_path(root: &Path, file: &Path, active: &[&str]) -> io::Result<Vec<Diagnostic>> {
    let rel = file.strip_prefix(root).unwrap_or(file);
    let rel = rel.to_string_lossy().replace('\\', "/");
    let source = fs::read_to_string(file)?;
    Ok(lint_source(&rel, &source, active))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Locate the repo root by walking up from `start` until a directory
/// containing `rust/src` appears (so the binary works from any subdir and
/// from `cargo run -p axdt-lint` in the workspace root).
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = start.to_path_buf();
    for _ in 0..16 {
        if cur.join("rust/src").is_dir() {
            return Some(cur);
        }
        if !cur.pop() {
            break;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_root_walks_up() {
        // The crate lives at <root>/tools/axdt-lint, so walking up from
        // the manifest dir must find the repo root.
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = find_root(&here).expect("repo root above tools/axdt-lint");
        assert!(root.join("rust/src").is_dir());
    }

    #[test]
    fn the_tree_is_clean() {
        // The acceptance bar: the linter exits 0 on the repo itself.
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = find_root(&here).expect("repo root");
        let diags = lint_tree(&root, &[]).expect("lint walks the tree");
        assert!(
            diags.is_empty(),
            "tree has lint violations:\n{}",
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
