//! axdt-lint: syntax-aware architectural lints for the axdt tree.
//!
//! The codebase has three load-bearing seams — every deadline decision
//! reads the injected `Clock` (util::clock), every evaluation flows
//! through the two-phase `submit`/`wait` ticket path, and the trace
//! journal records causally before the sends it describes — plus hard
//! worker-survival rules (typed errors, never panics).  Grep guards
//! cannot see comments, strings, or test regions; this crate lexes every
//! Rust source (no `syn`, zero dependencies, offline-green), recovers
//! function boundaries and def-use chains ([`parser`], [`dataflow`]) and
//! enforces the rule registry in [`rules`] with `file:line:col`
//! diagnostics and justified `// axdt-lint: allow(<rule>): <why>`
//! suppressions.  `--format sarif` / `--format json` emit
//! machine-readable output ([`sarif`]) for code-scanning upload.
//!
//! Run it as `cargo run -p axdt-lint` (or `make lint`); CI runs it as a
//! required job.  Per-rule documentation lives in `RULES.md` next to
//! this crate.

pub mod dataflow;
pub mod lexer;
pub mod parser;
pub mod rules;
pub mod sarif;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{lint_source, rule_ids, Diagnostic, ALL_RULES};

/// Directories under the repo root the full-tree lint walks.  Rules are
/// path-scoped (see `rules::scope_for`), so walking a directory no rule
/// targets is free — and keeps future rules one table entry away.
/// `examples/` and `tools/` are included so the linter dogfoods itself;
/// fixture trees (intentional violations) are skipped in `collect_rs`.
const LINT_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples", "tools"];

/// Lint the whole tree under `root` (the repo checkout).  `active` is
/// the rule filter (empty = all rules).  Lock-order edges are aggregated
/// across every file before cycle detection, so an AB/BA acquisition
/// split across two modules is still reported.  Returns diagnostics
/// sorted by path.
pub fn lint_tree(root: &Path, active: &[&str]) -> io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    for dir in LINT_DIRS {
        let abs = root.join(dir);
        if abs.is_dir() {
            collect_rs(&abs, &mut files)?;
        }
    }
    files.sort();
    let mut out = Vec::new();
    let mut edges = Vec::new();
    for file in files {
        let rel = file.strip_prefix(root).unwrap_or(&file);
        let rel = rel.to_string_lossy().replace('\\', "/");
        let source = fs::read_to_string(&file)?;
        let mut analysis = rules::analyze_source(&rel, &source, active);
        out.append(&mut analysis.diags);
        edges.append(&mut analysis.lock_edges);
    }
    out.extend(rules::lock_cycles(&edges));
    out.sort_by(|a, b| {
        (a.path.clone(), a.line, a.col, a.rule).cmp(&(b.path.clone(), b.line, b.col, b.rule))
    });
    Ok(out)
}

/// Lint one file, reporting diagnostics under its path relative to
/// `root` (rule scoping runs on that relative path).
pub fn lint_path(root: &Path, file: &Path, active: &[&str]) -> io::Result<Vec<Diagnostic>> {
    let rel = file.strip_prefix(root).unwrap_or(file);
    let rel = rel.to_string_lossy().replace('\\', "/");
    let source = fs::read_to_string(file)?;
    Ok(lint_source(&rel, &source, active))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            // Fixture trees hold intentional violations; `target/` is
            // build output.
            let name = entry.file_name();
            if name == "fixtures" || name == "target" {
                continue;
            }
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Locate the repo root by walking up from `start` until a directory
/// containing `rust/src` appears (so the binary works from any subdir and
/// from `cargo run -p axdt-lint` in the workspace root).
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = start.to_path_buf();
    for _ in 0..16 {
        if cur.join("rust/src").is_dir() {
            return Some(cur);
        }
        if !cur.pop() {
            break;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_root_walks_up() {
        // The crate lives at <root>/tools/axdt-lint, so walking up from
        // the manifest dir must find the repo root.
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = find_root(&here).expect("repo root above tools/axdt-lint");
        assert!(root.join("rust/src").is_dir());
    }

    #[test]
    fn the_tree_is_clean() {
        // The acceptance bar: the linter exits 0 on the repo itself.
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = find_root(&here).expect("repo root");
        let diags = lint_tree(&root, &[]).expect("lint walks the tree");
        assert!(
            diags.is_empty(),
            "tree has lint violations:\n{}",
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn tree_walk_covers_tools_and_skips_fixtures() {
        let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = find_root(&here).expect("repo root");
        let mut files = Vec::new();
        for dir in LINT_DIRS {
            let abs = root.join(dir);
            if abs.is_dir() {
                collect_rs(&abs, &mut files).expect("walk");
            }
        }
        let rels: Vec<String> = files
            .iter()
            .map(|f| {
                f.strip_prefix(&root)
                    .unwrap_or(f)
                    .to_string_lossy()
                    .replace('\\', "/")
            })
            .collect();
        assert!(
            rels.iter().any(|r| r == "tools/axdt-lint/src/lib.rs"),
            "dogfood: the linter lints its own sources"
        );
        assert!(
            !rels.iter().any(|r| r.contains("/fixtures/")),
            "fixtures are intentional violations and must be skipped: {rels:?}"
        );
    }
}
