//! A small intra-procedural dataflow core: def-use chains over `let`
//! bindings and a call-context index, shared by the flow rules
//! (`ticket-leak`, `clock-taint`, `lock-order`).
//!
//! The model is deliberately modest — single-name `let` bindings,
//! linear use scanning to the end of the function, calls identified by
//! their callee identifier — because the architectural seams it guards
//! are written in exactly that style.  Destructuring patterns and
//! reassignments are not tracked (conservative: no diagnostic), and
//! closures are analyzed as part of their enclosing function.

use crate::lexer::{TokKind, Token};
use crate::parser::{matching_paren, statement_end};

/// One `let` binding: `let [mut] NAME [: Type] = INIT ;`.
#[derive(Debug, Clone)]
pub struct Binding {
    pub name: String,
    /// Token index of the binding name.
    pub name_idx: usize,
    /// Token range `[start, end)` of the initializer expression.
    pub init: (usize, usize),
    /// Token index of the terminating `;` (or the statement limit).
    pub stmt_end: usize,
}

/// Extract single-name `let` bindings in `range` (token indices,
/// half-open).  Destructuring patterns (`let (a, b) =`, `let Some(x) =`)
/// are skipped — the flow rules treat them conservatively.
pub fn bindings(toks: &[Token], range: (usize, usize)) -> Vec<Binding> {
    let (start, limit) = range;
    let mut out = Vec::new();
    let mut i = start;
    while i < limit {
        if !toks[i].is_ident("let") {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if toks.get(j).is_some_and(|t| t.is_ident("mut")) {
            j += 1;
        }
        let Some(name) = toks.get(j).and_then(|t| match &t.kind {
            TokKind::Ident(s) => Some(s.clone()),
            _ => None,
        }) else {
            i += 1;
            continue;
        };
        let name_idx = j;
        // A simple binding continues with `:` (typed) or `=`; anything
        // else (`(`, `{`, another ident) is a pattern we skip.
        let eq = match toks.get(j + 1) {
            Some(t) if t.is_punct('=') && !toks.get(j + 2).is_some_and(|n| n.is_punct('=')) => {
                Some(j + 1)
            }
            Some(t) if t.is_punct(':') => find_eq_after_type(toks, j + 2, limit),
            _ => None,
        };
        let Some(eq) = eq else {
            i = j + 1;
            continue;
        };
        let end = statement_end(toks, eq + 1, limit);
        out.push(Binding {
            name,
            name_idx,
            init: (eq + 1, end),
            stmt_end: end,
        });
        i = end + 1;
    }
    out
}

/// Scan a type annotation for the `=` that starts the initializer,
/// tracking angle-bracket depth so associated-type bindings
/// (`Box<dyn Iterator<Item = u32>>`) don't end the type early.  `->`
/// inside `Fn() -> R` sugar is ignored for angle counting.
fn find_eq_after_type(toks: &[Token], from: usize, limit: usize) -> Option<usize> {
    let mut angle = 0i64;
    let mut depth = 0i64;
    let mut k = from;
    while k < limit {
        let t = &toks[k];
        if t.is_punct('(') || t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            depth -= 1;
        } else if t.is_punct('<') {
            angle += 1;
        } else if t.is_punct('>') {
            // `->` is function-sugar, not a closing angle.
            if !toks.get(k.wrapping_sub(1)).is_some_and(|p| p.is_punct('-')) {
                angle -= 1;
            }
        } else if t.is_punct('=') && angle <= 0 && depth <= 0 {
            return Some(k);
        } else if t.is_punct(';') && depth <= 0 {
            return None; // `let x: T;` — no initializer.
        }
        k += 1;
    }
    None
}

/// Does `range` contain a call to one of `names` (identifier directly
/// followed by `(`)?  Returns the index of the callee token.
pub fn find_call(toks: &[Token], range: (usize, usize), names: &[&str]) -> Option<usize> {
    let (start, end) = range;
    (start..end.min(toks.len().saturating_sub(1))).find(|&k| {
        names.iter().any(|n| toks[k].is_ident(n)) && toks[k + 1].is_punct('(')
    })
}

/// Call-context index: for every token, the chain of enclosing calls.
///
/// Built once per function.  Parens without a callee (tuples, grouping)
/// are recorded as anonymous nodes, so [`CallIndex::governing_call`]
/// can skip them and find the nearest *named* call — `push((t, c))`
/// governs `t` even though the tuple paren is in between.
pub struct CallIndex {
    /// Per-token: index into `nodes` of the innermost enclosing paren
    /// group (usize::MAX = none).
    node_of: Vec<usize>,
    /// (callee name or None, parent node or usize::MAX).
    nodes: Vec<(Option<String>, usize)>,
    base: usize,
}

/// Keywords that look like callees when followed by `(` but are not.
const NOT_CALLEES: &[&str] = &[
    "if", "while", "for", "match", "return", "in", "loop", "else", "fn", "move",
];

impl CallIndex {
    pub fn build(toks: &[Token], range: (usize, usize)) -> CallIndex {
        let (start, end) = range;
        let mut node_of = vec![usize::MAX; end.saturating_sub(start)];
        let mut nodes: Vec<(Option<String>, usize)> = Vec::new();
        let mut stack: Vec<usize> = Vec::new();
        for k in start..end.min(toks.len()) {
            let t = &toks[k];
            if t.is_punct('(') {
                let callee = k.checked_sub(1).and_then(|p| match &toks[p].kind {
                    TokKind::Ident(s) if !NOT_CALLEES.contains(&s.as_str()) => Some(s.clone()),
                    // Macro call `name!(..)`.
                    TokKind::Punct('!') => p.checked_sub(1).and_then(|q| match &toks[q].kind {
                        TokKind::Ident(s) => Some(s.clone()),
                        _ => None,
                    }),
                    _ => None,
                });
                let parent = stack.last().copied().unwrap_or(usize::MAX);
                nodes.push((callee, parent));
                stack.push(nodes.len() - 1);
                node_of[k - start] = stack.last().copied().unwrap_or(usize::MAX);
            } else {
                node_of[k - start] = stack.last().copied().unwrap_or(usize::MAX);
                if t.is_punct(')') {
                    stack.pop();
                }
            }
        }
        CallIndex { node_of, nodes, base: start }
    }

    /// The nearest enclosing *named* call of token `idx` (skipping
    /// anonymous paren groups), if any.
    pub fn governing_call(&self, idx: usize) -> Option<(&str, usize)> {
        let mut node = *self.node_of.get(idx.checked_sub(self.base)?)?;
        let mut depth = 0usize;
        while node != usize::MAX && depth < 64 {
            let (callee, parent) = &self.nodes[node];
            if let Some(name) = callee {
                return Some((name.as_str(), node));
            }
            node = *parent;
            depth += 1;
        }
        None
    }

    /// Like [`Self::governing_call`] but returns the whole chain of
    /// named enclosing calls, innermost first.
    pub fn call_chain(&self, idx: usize) -> Vec<&str> {
        let mut out = Vec::new();
        let Some(slot) = idx.checked_sub(self.base) else { return out };
        let mut node = self.node_of.get(slot).copied().unwrap_or(usize::MAX);
        let mut depth = 0usize;
        while node != usize::MAX && depth < 64 {
            let (callee, parent) = &self.nodes[node];
            if let Some(name) = callee {
                out.push(name.as_str());
            }
            node = *parent;
            depth += 1;
        }
        out
    }
}

/// Uses of `name` as a standalone identifier in `range` strictly after
/// `after` — field/method positions (`x.name`) and path segments
/// (`m::name`) are excluded, so a field or item that happens to share
/// the binding's name never counts as a use.  Struct-literal field
/// values (`field: name`) DO count: the single `:` disambiguates.
pub fn uses_of(
    toks: &[Token],
    range: (usize, usize),
    name: &str,
    after: usize,
) -> Vec<usize> {
    let (start, end) = range;
    (start.max(after + 1)..end.min(toks.len()))
        .filter(|&k| {
            if !toks[k].is_ident(name) {
                return false;
            }
            let prev = |n: usize| k.checked_sub(n).map(|p| &toks[p]);
            let dotted = prev(1).is_some_and(|p| p.is_punct('.'));
            let pathed = prev(1).is_some_and(|p| p.is_punct(':'))
                && prev(2).is_some_and(|p| p.is_punct(':'));
            !dotted && !pathed
        })
        .collect()
}

/// The last identifier at paren-depth 0 in `range` — the lock-identity
/// heuristic for lockee expressions (`&shared.slots[shard].tx` → `tx`;
/// the index expression is inside `[..]` and ignored).
pub fn last_path_ident(toks: &[Token], range: (usize, usize)) -> Option<String> {
    let (start, end) = range;
    let mut depth = 0i64;
    let mut last = None;
    for k in start..end.min(toks.len()) {
        let t = &toks[k];
        if t.is_punct('(') || t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            depth -= 1;
        } else if depth == 0 {
            if let TokKind::Ident(s) = &t.kind {
                last = Some(s.clone());
            }
        }
    }
    last
}

/// Is the `(` at `open` the argument list of a method call
/// (`recv.name(..)`)?  Returns the receiver's trailing identifier.
pub fn method_receiver(toks: &[Token], callee_idx: usize) -> Option<String> {
    let dot = callee_idx.checked_sub(1)?;
    if !toks[dot].is_punct('.') {
        return None;
    }
    let recv = dot.checked_sub(1)?;
    match &toks[recv].kind {
        TokKind::Ident(s) => Some(s.clone()),
        _ => None,
    }
}

/// Argument token range of the call whose callee identifier is at
/// `callee_idx` (expects `callee (` shape): `(start, end)` half-open,
/// excluding the parens.
pub fn call_args(toks: &[Token], callee_idx: usize) -> Option<(usize, usize)> {
    let open = callee_idx + 1;
    if !toks.get(open).is_some_and(|t| t.is_punct('(')) {
        return None;
    }
    let close = matching_paren(toks, open)?;
    Some((open + 1, close))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn bindings_handle_types_generics_and_match_inits() {
        let src = "fn f() { let a = 1; let mut b: Box<dyn Iterator<Item = u32>> = make(); \
                   let c = match x { Some(v) => { v; v } None => 0 }; let (d, e) = pair(); }";
        let toks = lex(src).tokens;
        let bs = bindings(&toks, (0, toks.len()));
        let names: Vec<&str> = bs.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c"], "destructuring is skipped");
        // c's initializer spans the whole match, inner `;` included.
        let c = &bs[2];
        assert!(toks[c.stmt_end].is_punct(';'));
        assert!(toks[c.init.0].is_ident("match"));
    }

    #[test]
    fn governing_call_skips_tuple_parens() {
        let src = "fn f() { v.push((t, c)); w.wait(t2); }";
        let toks = lex(src).tokens;
        let ix = CallIndex::build(&toks, (0, toks.len()));
        let t_idx = toks.iter().position(|t| t.is_ident("t")).unwrap();
        assert_eq!(ix.governing_call(t_idx).map(|(n, _)| n), Some("push"));
        let t2_idx = toks.iter().position(|t| t.is_ident("t2")).unwrap();
        assert_eq!(ix.governing_call(t2_idx).map(|(n, _)| n), Some("wait"));
    }

    #[test]
    fn uses_exclude_field_positions() {
        let src = "fn f() { let t = g(); h(t); x.t; y::t; t.m(); }";
        let toks = lex(src).tokens;
        let bs = bindings(&toks, (0, toks.len()));
        let uses = uses_of(&toks, (0, toks.len()), "t", bs[0].name_idx);
        // h(t) and the receiver use t.m() — not x.t / y::t.
        assert_eq!(uses.len(), 2);
    }

    #[test]
    fn lock_identity_is_the_trailing_ident() {
        let src = "lock_recover(&shared.slots[shard].tx)";
        let toks = lex(src).tokens;
        let args = call_args(&toks, 0).unwrap();
        assert_eq!(last_path_ident(&toks, args).as_deref(), Some("tx"));
    }
}
