//! Item-scope recovery on top of the lexer: just enough syntax to give
//! the dataflow rules (`lock-order`, `ticket-leak`, `trace-ordering`,
//! `clock-taint`) function boundaries and brace-block structure.
//!
//! This is NOT a Rust parser.  It recovers:
//!
//! * every `fn` item (named functions at any nesting: free, in `impl`,
//!   in `mod`, nested inside another fn) with the token range of its
//!   `{..}` body;
//! * brace-pair matching inside a body, so a rule can ask "where does
//!   the block enclosing token `i` end" — the granularity guard
//!   liveness is defined at;
//! * statement boundaries (`;` at block depth), so temporaries can be
//!   scoped to their statement.
//!
//! Closures are deliberately *not* separate scopes: their tokens belong
//! to the enclosing function, which is the right treatment for
//! intra-procedural rules (a ticket captured and awaited inside a
//! closure still flows within the same function body).

use crate::lexer::Token;

/// One recovered `fn` item.
#[derive(Debug, Clone)]
pub struct FnInfo {
    /// The function's name.
    pub name: String,
    /// Token index of the `fn` keyword.
    pub fn_idx: usize,
    /// Token indices of the body's `{` and matching `}`.  `None` for
    /// bodyless declarations (trait methods, extern blocks).
    pub body: Option<(usize, usize)>,
}

impl FnInfo {
    /// Token range of the body interior (excludes the braces).
    pub fn interior(&self) -> Option<(usize, usize)> {
        self.body.map(|(open, close)| (open + 1, close))
    }
}

/// Recover every `fn` item in the token stream.  A `fn` token counts
/// when followed by an identifier (so function-pointer types `fn(u32)`
/// and the `Fn` traits never match).
pub fn functions(toks: &[Token]) -> Vec<FnInfo> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].is_ident("fn") {
            if let Some(name) = toks.get(i + 1).and_then(ident_of) {
                let body = find_body(toks, i + 2);
                out.push(FnInfo { name: name.to_string(), fn_idx: i, body });
                // Continue scanning INSIDE the body too: nested fns are
                // recovered as their own entries (callers subtract them
                // from the enclosing function's range).
            }
        }
        i += 1;
    }
    out
}

fn ident_of(t: &Token) -> Option<&str> {
    match &t.kind {
        crate::lexer::TokKind::Ident(s) => Some(s.as_str()),
        _ => None,
    }
}

/// From just past the fn name, find the body's `{..}`: skip balanced
/// `(..)` / `[..]` groups (parameters, const-generic arrays), stop at a
/// top-level `;` (bodyless declaration) or the first top-level `{`.
fn find_body(toks: &[Token], from: usize) -> Option<(usize, usize)> {
    let mut depth = 0i64;
    let mut j = from;
    while j < toks.len() {
        let t = &toks[j];
        if t.is_punct('(') || t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') {
            depth -= 1;
        } else if depth == 0 && t.is_punct(';') {
            return None;
        } else if depth == 0 && t.is_punct('{') {
            return matching_brace(toks, j).map(|close| (j, close));
        }
        j += 1;
    }
    None
}

/// Index of the `}` matching the `{` at `open`.
pub fn matching_brace(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Index of the `)` matching the `(` at `open`.
pub fn matching_paren(toks: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Close index of the innermost `{..}` block (within `body`) containing
/// token `idx` — where a `let`-bound guard acquired at `idx` dies.
/// Falls back to the body close itself.
pub fn enclosing_block_close(
    toks: &[Token],
    body: (usize, usize),
    idx: usize,
) -> usize {
    let (open, close) = body;
    let mut stack: Vec<usize> = Vec::new();
    let mut best = close;
    for k in open..=close.min(toks.len().saturating_sub(1)) {
        if toks[k].is_punct('{') {
            stack.push(k);
        } else if toks[k].is_punct('}') {
            if let Some(o) = stack.pop() {
                if o <= idx && idx <= k && k < best {
                    best = k;
                }
            }
        }
    }
    best
}

/// End of the statement containing `idx`: the next `;` at the same
/// brace/paren depth, or `limit` if the statement is a trailing
/// expression.  Depth counting starts at `idx`, so a `;` inside a
/// nested group (closure body, `match` arm block) does not terminate
/// the outer statement.
pub fn statement_end(toks: &[Token], idx: usize, limit: usize) -> usize {
    let mut depth = 0i64;
    let mut k = idx;
    while k < limit && k < toks.len() {
        let t = &toks[k];
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                return k;
            }
        } else if t.is_punct(';') && depth == 0 {
            return k;
        }
        k += 1;
    }
    limit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn recovers_nested_functions_with_bodies() {
        let src = r#"
            impl Foo {
                pub fn outer(&self) -> u64 {
                    fn inner(x: u64) -> u64 { x + 1 }
                    inner(2)
                }
            }
            trait T { fn decl(&self); }
            mod m { fn modfn() {} }
            type F = fn(u32) -> u32;
        "#;
        let toks = lex(src).tokens;
        let fns = functions(&toks);
        let names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["outer", "inner", "decl", "modfn"]);
        assert!(fns[0].body.is_some());
        assert!(fns[1].body.is_some());
        assert!(fns[2].body.is_none(), "trait declaration has no body");
        // inner's body nests inside outer's.
        let (oo, oc) = fns[0].body.unwrap();
        let (io, ic) = fns[1].body.unwrap();
        assert!(oo < io && ic < oc);
    }

    #[test]
    fn body_detection_skips_generics_and_where_clauses() {
        let src = "fn f<T: Fn() -> u32>(g: T) -> Vec<u32> where T: Send { g(); Vec::new() }";
        let toks = lex(src).tokens;
        let fns = functions(&toks);
        assert_eq!(fns.len(), 1);
        let (open, close) = fns[0].body.unwrap();
        assert!(toks[open].is_punct('{') && toks[close].is_punct('}'));
        assert_eq!(close, toks.len() - 1);
    }

    #[test]
    fn enclosing_block_and_statement_boundaries() {
        let src = "fn f() { let a = 1; { let b = 2; use_it(b); } let c = 3; }";
        let toks = lex(src).tokens;
        let fns = functions(&toks);
        let body = fns[0].body.unwrap();
        // Find the token index of ident `b` in `let b`.
        let b_idx = toks
            .iter()
            .position(|t| t.is_ident("b"))
            .unwrap();
        let close = enclosing_block_close(&toks, body, b_idx);
        // That close must come before `let c`.
        let c_idx = toks.iter().position(|t| t.is_ident("c")).unwrap();
        assert!(close < c_idx);
        // Statement end of `let a = 1;` is the first `;`.
        let a_idx = toks.iter().position(|t| t.is_ident("a")).unwrap();
        let end = statement_end(&toks, a_idx, body.1);
        assert!(toks[end].is_punct(';'));
        assert!(end < b_idx);
    }
}
